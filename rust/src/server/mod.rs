//! Network serving subsystem — the ingress path in front of the
//! [`coordinator`](crate::coordinator).
//!
//! The paper's headline numbers are *serving* numbers (22.6 KFPS,
//! 42.4 uJ/image on classification); streaming SNN accelerators treat
//! the host↔accelerator boundary as a first-class subsystem. This
//! module is that boundary as real code:
//!
//! * [`protocol`] — versioned, length-prefixed binary wire format
//!   (requests carry raw pixels or pre-encoded spike words; responses
//!   carry prediction + latency + worker id; typed error codes
//!   `BUSY` / `BAD_REQUEST` / `SHUTTING_DOWN` / `INTERNAL`). Two live
//!   versions: v1 (single-model) and v2 (`Infer`/`Info` carry a model
//!   selector); a gateway answers each request in the version it
//!   arrived with.
//! * [`reactor`] — the std-only readiness layer under the gateway:
//!   a `poll(2)`-shaped wrapper over raw syscalls (no `libc`), a
//!   self-pipe [`Waker`](reactor::Waker) for cross-thread poll
//!   interruption, and the growable [`RecvBuf`](reactor::RecvBuf)
//!   incremental-decode receive buffer.
//! * [`server`] — the TCP [`Gateway`]: a
//!   [`ModelRegistry`](crate::coordinator::ModelRegistry) of named
//!   models behind one port, N sharded reactor event loops (thread
//!   count O(shards + models), not O(connections)), pipelined
//!   requests, a connection cap plus per-connection write-backpressure
//!   bounds, per-model admission control that maps queue-full onto
//!   `BUSY` (shed load, never hang), per-model Prometheus metrics, and
//!   graceful drain-then-shutdown. v1 (no selector) traffic routes to
//!   the default model.
//! * [`client`] — a blocking, pipelining client library (speaks v2 by
//!   default; can be pinned to v1).
//! * [`loadgen`] — a multi-connection load generator (the
//!   `skydiver loadgen` CLI and the loopback serving bench), with a
//!   per-run model selector for mixed multi-model traffic and an
//!   optional priority class stamped on every request; beyond
//!   ~64 connections it multiplexes them over one nonblocking driver
//!   thread, so c10k-scale runs don't need c10k client threads.
//!
//! On top of admission control the gateway is *self-driving*: an
//! autoscale control thread resizes each model's worker pool between
//! `--workers-min` and `--workers-max` from queue pressure and
//! windowed p99 ([`crate::coordinator::Autoscaler`]); requests may
//! carry a [`Priority`](crate::coordinator::Priority) class extension
//! served by weighted-fair queueing; and under `--degrade reduce-t`
//! overload is answered with reduced-timestep inference — flagged to
//! v2 clients via a [`DegradeInfo`] response extension — instead of
//! `BUSY`.

pub mod client;
pub mod loadgen;
pub mod protocol;
pub mod reactor;
pub mod server;

pub use client::{Client, ServerInfo};
pub use loadgen::{LoadGenConfig, LoadGenReport, TrafficMode};
pub use protocol::{DegradeInfo, ErrorCode, ModelLoad, ProtoError,
                   RequestBody, RequestExts, ResponseBody, WirePayload,
                   WireRequest, WireResponse};
pub use server::{CounterSnapshot, Gateway, GatewayConfig,
                 GatewayReport, GatewayStop, ModelCounterSnapshot,
                 ModelReport};
