//! Multi-connection load generator for the TCP gateway.
//!
//! Opens `conns` connections, splits `frames` across them, and drives
//! each with window-based pipelining (`window` requests in flight per
//! connection). Latency is measured client-side per request
//! (send→matching response); throughput is total successful frames
//! over wall time. `BUSY` responses (shed load) are counted and —
//! optionally — retried with a small backoff, so an overloaded server
//! still converges instead of dropping work silently.

use std::collections::{HashMap, VecDeque};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, ensure, Result};

use crate::data::SplitMix64;
use crate::metrics::percentile;
use crate::snn::encode_phased_u8;

use super::client::{Client, ServerInfo};
use super::protocol::{ErrorCode, RequestBody, ResponseBody,
                      WirePayload, WireRequest, CONN_ERR_ID, NET_ANY};

/// Max resubmissions of one frame after `BUSY` before giving up.
const MAX_BUSY_RETRIES: u32 = 200;

/// Input spike-density distribution of the generated frames.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TrafficMode {
    /// ~1 in 4 frames dense-random, the rest ~10% sparse — the
    /// original mixed workload.
    #[default]
    Mixed,
    /// Heavy-tailed per-frame density: most frames nearly silent
    /// (~2%), a thin tail ramping to ~90% dense (`density = 0.02 +
    /// 0.9 u^5` on a per-frame uniform draw). This is the skew the
    /// cost-aware dispatch exists for: request *count* says nothing
    /// about the work a burst carries.
    Skewed,
}

impl TrafficMode {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "mixed" => TrafficMode::Mixed,
            "skewed" | "skew" => TrafficMode::Skewed,
            _ => return None,
        })
    }

    pub fn as_str(self) -> &'static str {
        match self {
            TrafficMode::Mixed => "mixed",
            TrafficMode::Skewed => "skewed",
        }
    }
}

#[derive(Debug, Clone)]
pub struct LoadGenConfig {
    pub addr: String,
    /// Target model (registry name); empty = the server's default
    /// model. Payload shapes follow the selected model's `Info`.
    pub model: String,
    /// Concurrent connections.
    pub conns: usize,
    /// Total frames across all connections.
    pub frames: usize,
    /// Per-connection pipelining window (requests in flight).
    pub window: usize,
    /// Pre-encode spike trains client-side (exercises the `Spikes`
    /// payload) instead of sending raw pixels.
    pub spikes: bool,
    /// Re-send frames shed with `BUSY` (with backoff) instead of
    /// counting them as terminal.
    pub retry_busy: bool,
    /// Input-density distribution of the generated frames.
    pub traffic: TrafficMode,
    pub seed: u64,
}

impl Default for LoadGenConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:7878".into(),
            model: String::new(),
            conns: 4,
            frames: 1000,
            window: 8,
            spikes: false,
            retry_busy: true,
            traffic: TrafficMode::Mixed,
            seed: 0x10AD,
        }
    }
}

/// Aggregated result of one load-generation run.
#[derive(Debug, Clone, Default)]
pub struct LoadGenReport {
    /// Request frames written (including retries).
    pub sent: u64,
    /// Successful predictions.
    pub ok: u64,
    /// `BUSY` responses observed (shed load; retries count each time).
    pub busy: u64,
    /// Terminal failures (non-busy errors, or busy past the retry cap).
    pub errors: u64,
    pub wall_secs: f64,
    /// Successful frames per second of wall time, all connections.
    pub fps: f64,
    /// Client-side latency percentiles over successful frames (us).
    pub p50_us: u64,
    pub p95_us: u64,
    pub p99_us: u64,
    pub mean_us: f64,
    /// Successful frames per connection.
    pub per_conn_ok: Vec<u64>,
    /// All client-side latencies (us), sorted — for benches that need
    /// the full distribution.
    pub latencies_us: Vec<u64>,
}

struct ConnResult {
    sent: u64,
    ok: u64,
    busy: u64,
    errors: u64,
    latencies_us: Vec<u64>,
}

/// Deterministic pixel workload, regenerable from `(seed, id)` so
/// busy retries resend identical bytes and tests can reproduce the
/// exact frames a loadgen run sent (the hermetic balance tests do).
pub fn gen_pixels(n: usize, seed: u64, id: u64, traffic: TrafficMode)
                  -> Vec<u8> {
    let mut rng = SplitMix64::new(seed ^ id.wrapping_mul(0x9E37_79B9));
    match traffic {
        // ~1 in 4 frames dense-random (expensive), the rest ~10%
        // sparse (cheap).
        TrafficMode::Mixed => {
            let dense = id % 4 == 0;
            (0..n)
                .map(|_| {
                    if dense {
                        rng.next_below(256) as u8
                    } else if rng.next_below(100) < 10 {
                        rng.next_below(256) as u8
                    } else {
                        0
                    }
                })
                .collect()
        }
        // Heavy-tailed density: one uniform draw per frame sets its
        // spike density at `0.02 + 0.9 u^5` — mostly near-silent
        // frames with a thin, very dense tail.
        TrafficMode::Skewed => {
            let u = rng.next_below(1_000_000) as f64 / 1e6;
            let density = 0.02 + 0.90 * u.powi(5);
            let thresh = (density * 10_000.0) as u64;
            (0..n)
                .map(|_| {
                    if rng.next_below(10_000) < thresh {
                        rng.next_below(256) as u8
                    } else {
                        0
                    }
                })
                .collect()
        }
    }
}

fn make_payload(info: &ServerInfo, seed: u64, id: u64, spikes: bool,
                traffic: TrafficMode) -> WirePayload {
    let pixels = gen_pixels(info.pixels_len(), seed, id, traffic);
    if !spikes {
        return WirePayload::Pixels(pixels);
    }
    let train = encode_phased_u8(&pixels, info.c, info.h, info.w,
                                 info.timesteps);
    let mut words = Vec::new();
    for map in &train {
        for ch in 0..info.c {
            words.extend_from_slice(map.channel_words(ch));
        }
    }
    WirePayload::Spikes {
        timesteps: info.timesteps as u32,
        words,
    }
}

#[allow(clippy::too_many_arguments)]
fn run_conn(addr: &str, model: &str, info: &ServerInfo, frames: usize,
            window: usize, spikes: bool, retry_busy: bool,
            traffic: TrafficMode, seed: u64) -> Result<ConnResult> {
    let mut client = Client::connect(addr)?;
    client.set_read_timeout(Some(Duration::from_secs(60)))?;
    let mut to_send: VecDeque<(u64, u32)> =
        (0..frames as u64).map(|id| (id, 0)).collect();
    let mut inflight: HashMap<u64, (Instant, u32)> = HashMap::new();
    let mut latencies_us = Vec::with_capacity(frames);
    let (mut sent, mut ok, mut busy, mut errors) = (0u64, 0u64, 0u64,
                                                    0u64);
    while ok + errors < frames as u64 {
        while inflight.len() < window {
            let Some((id, attempts)) = to_send.pop_front() else {
                break;
            };
            let payload = make_payload(info, seed, id, spikes, traffic);
            client.send(&WireRequest {
                id,
                body: RequestBody::Infer {
                    net: NET_ANY,
                    model: model.to_string(),
                    payload,
                },
            })?;
            inflight.insert(id, (Instant::now(), attempts));
            sent += 1;
        }
        if inflight.is_empty() {
            break;
        }
        let resp = client.recv()?;
        if resp.id == CONN_ERR_ID {
            // Connection-level error (shed connection, framing
            // damage): the whole connection is failing, not one frame.
            match resp.body {
                ResponseBody::Error { code, detail } => {
                    return Err(anyhow!(
                        "connection-level {}: {detail}", code.as_str()));
                }
                other => {
                    return Err(anyhow!(
                        "unexpected connection-level response: \
                         {other:?}"));
                }
            }
        }
        let (t0, attempts) = inflight.remove(&resp.id).ok_or_else(
            || anyhow!("response for unknown id {}", resp.id))?;
        match resp.body {
            ResponseBody::Infer { .. } => {
                ok += 1;
                latencies_us.push(t0.elapsed().as_micros() as u64);
            }
            ResponseBody::Error { code: ErrorCode::Busy, .. } => {
                busy += 1;
                if retry_busy && attempts < MAX_BUSY_RETRIES {
                    // Back off briefly so the shedding server can
                    // drain, then requeue the same frame.
                    thread::sleep(Duration::from_millis(
                        (1 + attempts as u64 / 10).min(10)));
                    to_send.push_back((resp.id, attempts + 1));
                } else {
                    errors += 1;
                }
            }
            ResponseBody::Error { .. } => errors += 1,
            _ => errors += 1,
        }
    }
    Ok(ConnResult { sent, ok, busy, errors, latencies_us })
}

/// Run a full multi-connection load generation against `cfg.addr`,
/// targeting `cfg.model` (empty = the server's default model).
pub fn run(cfg: &LoadGenConfig) -> Result<LoadGenReport> {
    ensure!(cfg.conns > 0, "loadgen needs at least one connection");
    let info = Client::connect(&cfg.addr)?.info_model(&cfg.model)?;
    let window = cfg.window.max(1);

    let t0 = Instant::now();
    let results: Vec<Result<ConnResult>> = thread::scope(|s| {
        let info = &info;
        let handles: Vec<_> = (0..cfg.conns)
            .map(|i| {
                let n = cfg.frames / cfg.conns
                    + usize::from(i < cfg.frames % cfg.conns);
                let seed =
                    cfg.seed.wrapping_add(0xC0FF_EE00 * i as u64);
                s.spawn(move || {
                    run_conn(&cfg.addr, &cfg.model, info, n, window,
                             cfg.spikes, cfg.retry_busy, cfg.traffic,
                             seed)
                })
            })
            .collect();
        handles.into_iter()
            .map(|h| h.join().unwrap_or_else(
                |_| Err(anyhow!("loadgen connection thread panicked"))))
            .collect()
    });
    let wall_secs = t0.elapsed().as_secs_f64();

    let mut report = LoadGenReport {
        wall_secs,
        per_conn_ok: Vec::with_capacity(cfg.conns),
        ..Default::default()
    };
    let mut all_lat: Vec<u64> = Vec::with_capacity(cfg.frames);
    for res in results {
        let r = res?;
        report.sent += r.sent;
        report.ok += r.ok;
        report.busy += r.busy;
        report.errors += r.errors;
        report.per_conn_ok.push(r.ok);
        all_lat.extend_from_slice(&r.latencies_us);
    }
    all_lat.sort_unstable();
    report.fps = report.ok as f64 / wall_secs.max(1e-9);
    report.p50_us = percentile(&all_lat, 50.0);
    report.p95_us = percentile(&all_lat, 95.0);
    report.p99_us = percentile(&all_lat, 99.0);
    report.mean_us = if all_lat.is_empty() {
        0.0
    } else {
        all_lat.iter().sum::<u64>() as f64 / all_lat.len() as f64
    };
    report.latencies_us = all_lat;
    Ok(report)
}
