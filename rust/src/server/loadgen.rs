//! Multi-connection load generator for the TCP gateway.
//!
//! Opens `conns` connections, splits `frames` across them, and drives
//! each with window-based pipelining (`window` requests in flight per
//! connection). Latency is measured client-side per request
//! (send→matching response); throughput is total successful frames
//! over wall time. `BUSY` responses (shed load) are counted and —
//! optionally — retried with a small backoff, so an overloaded server
//! still converges instead of dropping work silently.
//!
//! Two drivers share one workload definition (same seeds, same
//! per-connection frame split, same windowing and busy-retry policy):
//! below [`MULTIPLEX_CONNS`] connections each gets a blocking client
//! thread; at or above it, every connection is multiplexed over one
//! nonblocking poll loop ([`reactor`](super::reactor)), so c10k-scale
//! runs cost fds, not client threads.

use std::collections::{HashMap, VecDeque};
use std::io::{self, Write};
use std::net::TcpStream;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::data::SplitMix64;
use crate::metrics::percentile;
use crate::snn::encode_phased_u8;

use super::client::{Client, ServerInfo};
use super::protocol::{parse_frame, ErrorCode, RequestBody,
                      RequestExts, ResponseBody, WirePayload,
                      WireRequest, WireResponse, CONN_ERR_ID,
                      HEADER_LEN, KIND_RESPONSE, NET_ANY};
use super::reactor::{self, PollFd, RecvBuf, POLLIN, POLLOUT};

/// Max resubmissions of one frame after `BUSY` before giving up.
const MAX_BUSY_RETRIES: u32 = 200;

/// Ceiling for one busy-retry backoff step.
const BUSY_BACKOFF_CAP_MS: u64 = 50;

/// Capped jittered exponential backoff for `BUSY` retries: the step
/// doubles per attempt up to `BUSY_BACKOFF_CAP_MS`, and the actual
/// wait is drawn uniformly from the upper half of the step, so a
/// window's worth of shed requests decorrelates instead of
/// re-slamming the queue in lockstep. Deterministic given the rng —
/// the cluster router reuses the same curve for failover re-dispatch.
pub fn busy_backoff(rng: &mut SplitMix64, attempts: u32) -> Duration {
    let step = (1u64 << attempts.min(6)).min(BUSY_BACKOFF_CAP_MS);
    let half = (step / 2).max(1);
    Duration::from_millis(half + rng.next_below(half + 1))
}

/// At or above this many connections, [`run`] switches from
/// one-thread-per-connection to the single-threaded multiplexed
/// driver (`conns` threads would stop measuring the *server* well
/// before c10k).
pub const MULTIPLEX_CONNS: usize = 64;

/// Abort a multiplexed run if no response lands for this long.
const MUX_STALL_TIMEOUT: Duration = Duration::from_secs(120);

/// Input spike-density distribution of the generated frames.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TrafficMode {
    /// ~1 in 4 frames dense-random, the rest ~10% sparse — the
    /// original mixed workload.
    #[default]
    Mixed,
    /// Heavy-tailed per-frame density: most frames nearly silent
    /// (~2%), a thin tail ramping to ~90% dense (`density = 0.02 +
    /// 0.9 u^5` on a per-frame uniform draw). This is the skew the
    /// cost-aware dispatch exists for: request *count* says nothing
    /// about the work a burst carries.
    Skewed,
}

impl TrafficMode {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "mixed" => TrafficMode::Mixed,
            "skewed" | "skew" => TrafficMode::Skewed,
            _ => return None,
        })
    }

    pub fn as_str(self) -> &'static str {
        match self {
            TrafficMode::Mixed => "mixed",
            TrafficMode::Skewed => "skewed",
        }
    }
}

#[derive(Debug, Clone)]
pub struct LoadGenConfig {
    pub addr: String,
    /// Target model (registry name); empty = the server's default
    /// model. Payload shapes follow the selected model's `Info`.
    pub model: String,
    /// Concurrent connections.
    pub conns: usize,
    /// Total frames across all connections.
    pub frames: usize,
    /// Per-connection pipelining window (requests in flight).
    pub window: usize,
    /// Pre-encode spike trains client-side (exercises the `Spikes`
    /// payload) instead of sending raw pixels.
    pub spikes: bool,
    /// Re-send frames shed with `BUSY` (with backoff) instead of
    /// counting them as terminal.
    pub retry_busy: bool,
    /// Input-density distribution of the generated frames.
    pub traffic: TrafficMode,
    /// Wire priority class sent with every request (`Some(0)` high,
    /// `Some(1)` normal, `Some(2)` low); `None` omits the extension
    /// and the server defaults to normal.
    pub priority: Option<u8>,
    pub seed: u64,
}

impl Default for LoadGenConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:7878".into(),
            model: String::new(),
            conns: 4,
            frames: 1000,
            window: 8,
            spikes: false,
            retry_busy: true,
            traffic: TrafficMode::Mixed,
            priority: None,
            seed: 0x10AD,
        }
    }
}

/// Aggregated result of one load-generation run.
#[derive(Debug, Clone, Default)]
pub struct LoadGenReport {
    /// Request frames written (including retries).
    pub sent: u64,
    /// Successful predictions.
    pub ok: u64,
    /// `BUSY` responses observed (shed load; retries count each time).
    pub busy: u64,
    /// Successful responses served at reduced timesteps (a subset of
    /// `ok` — degraded, not lost).
    pub degraded: u64,
    /// Terminal failures (non-busy errors, or busy past the retry cap).
    pub errors: u64,
    pub wall_secs: f64,
    /// Successful frames per second of wall time, all connections.
    pub fps: f64,
    /// Client-side latency percentiles over successful frames (us).
    pub p50_us: u64,
    pub p95_us: u64,
    pub p99_us: u64,
    pub mean_us: f64,
    /// Successful frames per connection.
    pub per_conn_ok: Vec<u64>,
    /// All client-side latencies (us), sorted — for benches that need
    /// the full distribution.
    pub latencies_us: Vec<u64>,
}

struct ConnResult {
    sent: u64,
    ok: u64,
    busy: u64,
    degraded: u64,
    errors: u64,
    latencies_us: Vec<u64>,
}

/// Deterministic pixel workload, regenerable from `(seed, id)` so
/// busy retries resend identical bytes and tests can reproduce the
/// exact frames a loadgen run sent (the hermetic balance tests do).
pub fn gen_pixels(n: usize, seed: u64, id: u64, traffic: TrafficMode)
                  -> Vec<u8> {
    let mut rng = SplitMix64::new(seed ^ id.wrapping_mul(0x9E37_79B9));
    match traffic {
        // ~1 in 4 frames dense-random (expensive), the rest ~10%
        // sparse (cheap).
        TrafficMode::Mixed => {
            let dense = id % 4 == 0;
            (0..n)
                .map(|_| {
                    if dense {
                        rng.next_below(256) as u8
                    } else if rng.next_below(100) < 10 {
                        rng.next_below(256) as u8
                    } else {
                        0
                    }
                })
                .collect()
        }
        // Heavy-tailed density: one uniform draw per frame sets its
        // spike density at `0.02 + 0.9 u^5` — mostly near-silent
        // frames with a thin, very dense tail.
        TrafficMode::Skewed => {
            let u = rng.next_below(1_000_000) as f64 / 1e6;
            let density = 0.02 + 0.90 * u.powi(5);
            let thresh = (density * 10_000.0) as u64;
            (0..n)
                .map(|_| {
                    if rng.next_below(10_000) < thresh {
                        rng.next_below(256) as u8
                    } else {
                        0
                    }
                })
                .collect()
        }
    }
}

fn make_payload(info: &ServerInfo, seed: u64, id: u64, spikes: bool,
                traffic: TrafficMode) -> WirePayload {
    let pixels = gen_pixels(info.pixels_len(), seed, id, traffic);
    if !spikes {
        return WirePayload::Pixels(pixels);
    }
    let train = encode_phased_u8(&pixels, info.c, info.h, info.w,
                                 info.timesteps);
    let mut words = Vec::new();
    for map in &train {
        for ch in 0..info.c {
            words.extend_from_slice(map.channel_words(ch));
        }
    }
    WirePayload::Spikes {
        timesteps: info.timesteps as u32,
        words,
    }
}

#[allow(clippy::too_many_arguments)]
fn run_conn(addr: &str, model: &str, info: &ServerInfo, frames: usize,
            window: usize, spikes: bool, retry_busy: bool,
            traffic: TrafficMode, priority: Option<u8>, seed: u64)
            -> Result<ConnResult> {
    let mut client =
        Client::connect_timeout(addr, Duration::from_secs(5))?;
    client.set_read_timeout(Some(Duration::from_secs(60)))?;
    let exts = RequestExts { trace: None, priority };
    let mut backoff_rng = SplitMix64::new(seed ^ 0xB0FF_B0FF);
    let mut to_send: VecDeque<(u64, u32)> =
        (0..frames as u64).map(|id| (id, 0)).collect();
    let mut inflight: HashMap<u64, (Instant, u32)> = HashMap::new();
    let mut latencies_us = Vec::with_capacity(frames);
    let (mut sent, mut ok, mut busy, mut degraded, mut errors) =
        (0u64, 0u64, 0u64, 0u64, 0u64);
    while ok + errors < frames as u64 {
        while inflight.len() < window {
            let Some((id, attempts)) = to_send.pop_front() else {
                break;
            };
            let payload = make_payload(info, seed, id, spikes, traffic);
            client.send_with_exts(&WireRequest {
                id,
                body: RequestBody::Infer {
                    net: NET_ANY,
                    model: model.to_string(),
                    payload,
                },
            }, &exts)?;
            inflight.insert(id, (Instant::now(), attempts));
            sent += 1;
        }
        if inflight.is_empty() {
            break;
        }
        let (resp, degrade) = client.recv_ext()?;
        if resp.id == CONN_ERR_ID {
            // Connection-level error (shed connection, framing
            // damage): the whole connection is failing, not one frame.
            match resp.body {
                ResponseBody::Error { code, detail } => {
                    return Err(anyhow!(
                        "connection-level {}: {detail}", code.as_str()));
                }
                other => {
                    return Err(anyhow!(
                        "unexpected connection-level response: \
                         {other:?}"));
                }
            }
        }
        let (t0, attempts) = inflight.remove(&resp.id).ok_or_else(
            || anyhow!("response for unknown id {}", resp.id))?;
        match resp.body {
            ResponseBody::Infer { .. } => {
                ok += 1;
                if degrade.is_some() {
                    degraded += 1;
                }
                latencies_us.push(t0.elapsed().as_micros() as u64);
            }
            ResponseBody::Error { code: ErrorCode::Busy, .. } => {
                busy += 1;
                if retry_busy && attempts < MAX_BUSY_RETRIES {
                    // Back off (capped, jittered) so the shedding
                    // server can drain, then requeue the same frame.
                    thread::sleep(busy_backoff(&mut backoff_rng,
                                               attempts));
                    to_send.push_back((resp.id, attempts + 1));
                } else {
                    errors += 1;
                }
            }
            ResponseBody::Error { .. } => errors += 1,
            _ => errors += 1,
        }
    }
    Ok(ConnResult { sent, ok, busy, degraded, errors, latencies_us })
}

/// Per-connection frame count: `frames` split as evenly as the
/// remainder allows (first `frames % conns` connections get one
/// extra). Both drivers use this split, so switching drivers never
/// changes the workload.
fn conn_frames(cfg: &LoadGenConfig, i: usize) -> usize {
    cfg.frames / cfg.conns + usize::from(i < cfg.frames % cfg.conns)
}

/// Per-connection workload seed (shared by both drivers, and by the
/// hermetic tests that regenerate a run's exact frames).
fn conn_seed(cfg: &LoadGenConfig, i: usize) -> u64 {
    cfg.seed.wrapping_add(0xC0FF_EE00 * i as u64)
}

fn aggregate(results: Vec<ConnResult>, wall_secs: f64, frames: usize)
             -> LoadGenReport {
    let mut report = LoadGenReport {
        wall_secs,
        per_conn_ok: Vec::with_capacity(results.len()),
        ..Default::default()
    };
    let mut all_lat: Vec<u64> = Vec::with_capacity(frames);
    for r in results {
        report.sent += r.sent;
        report.ok += r.ok;
        report.busy += r.busy;
        report.degraded += r.degraded;
        report.errors += r.errors;
        report.per_conn_ok.push(r.ok);
        all_lat.extend_from_slice(&r.latencies_us);
    }
    all_lat.sort_unstable();
    report.fps = report.ok as f64 / wall_secs.max(1e-9);
    report.p50_us = percentile(&all_lat, 50.0);
    report.p95_us = percentile(&all_lat, 95.0);
    report.p99_us = percentile(&all_lat, 99.0);
    report.mean_us = if all_lat.is_empty() {
        0.0
    } else {
        all_lat.iter().sum::<u64>() as f64 / all_lat.len() as f64
    };
    report.latencies_us = all_lat;
    report
}

/// Run a full multi-connection load generation against `cfg.addr`,
/// targeting `cfg.model` (empty = the server's default model). At
/// [`MULTIPLEX_CONNS`] connections or more the multiplexed driver is
/// used automatically.
pub fn run(cfg: &LoadGenConfig) -> Result<LoadGenReport> {
    ensure!(cfg.conns > 0, "loadgen needs at least one connection");
    let info = Client::connect_timeout(
        &cfg.addr, Duration::from_secs(5))?.info_model(&cfg.model)?;
    if cfg.conns >= MULTIPLEX_CONNS {
        return run_mux(cfg, &info, None).map(|(report, _)| report);
    }
    let window = cfg.window.max(1);

    let t0 = Instant::now();
    let results: Vec<Result<ConnResult>> = thread::scope(|s| {
        let info = &info;
        let handles: Vec<_> = (0..cfg.conns)
            .map(|i| {
                let n = conn_frames(cfg, i);
                let seed = conn_seed(cfg, i);
                s.spawn(move || {
                    run_conn(&cfg.addr, &cfg.model, info, n, window,
                             cfg.spikes, cfg.retry_busy, cfg.traffic,
                             cfg.priority, seed)
                })
            })
            .collect();
        handles.into_iter()
            .map(|h| h.join().unwrap_or_else(
                |_| Err(anyhow!("loadgen connection thread panicked"))))
            .collect()
    });
    let wall_secs = t0.elapsed().as_secs_f64();
    let results: Vec<ConnResult> =
        results.into_iter().collect::<Result<_>>()?;
    Ok(aggregate(results, wall_secs, cfg.frames))
}

/// One successful inference as the multiplexed driver observed it —
/// the response fields that are a pure function of the input frame,
/// for equivalence checks against the in-process `Service` path
/// (`latency_us`/`worker` vary run to run by design and are
/// excluded).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CollectedResponse {
    /// Loadgen connection index (0-based).
    pub conn: usize,
    /// Request id within that connection.
    pub id: u64,
    pub prediction: u32,
    pub output_counts: Vec<u32>,
}

/// Multiplexed run that also returns every successful response's
/// deterministic fields, sorted by `(conn, id)` — the c10k
/// equivalence test compares these byte-for-byte (after encoding)
/// with an in-process run over the same generated frames.
pub fn run_collect(cfg: &LoadGenConfig)
                   -> Result<(LoadGenReport, Vec<CollectedResponse>)> {
    ensure!(cfg.conns > 0, "loadgen needs at least one connection");
    let info = Client::connect_timeout(
        &cfg.addr, Duration::from_secs(5))?.info_model(&cfg.model)?;
    let (report, mut collected) = run_mux(cfg, &info, Some(Vec::new()))?;
    let mut out = collected.take().unwrap_or_default();
    out.sort_by_key(|c| (c.conn, c.id));
    Ok((report, out))
}

// ---------------------------------------------------- multiplexed driver

/// One connection's state inside the multiplexed driver — the same
/// bookkeeping `run_conn` keeps on its stack, made explicit.
struct MuxConn {
    stream: TcpStream,
    recv: RecvBuf,
    /// Encoded-but-unwritten request bytes (bounded by the window).
    out: Vec<u8>,
    out_pos: usize,
    to_send: VecDeque<(u64, u32)>,
    inflight: HashMap<u64, (Instant, u32)>,
    /// Busy-retried frames waiting out their backoff.
    delayed: Vec<(Instant, u64, u32)>,
    /// Jitter source for the busy-retry backoff deadlines.
    backoff_rng: SplitMix64,
    seed: u64,
    frames: u64,
    sent: u64,
    ok: u64,
    busy: u64,
    degraded: u64,
    errors: u64,
    latencies_us: Vec<u64>,
}

impl MuxConn {
    fn done(&self) -> bool {
        self.ok + self.errors >= self.frames
    }

    /// Move backoff-expired retries back onto the send queue; returns
    /// the earliest still-pending deadline.
    fn release_delayed(&mut self, now: Instant) -> Option<Instant> {
        let mut next = None;
        let mut i = 0;
        while i < self.delayed.len() {
            let (due, id, attempts) = self.delayed[i];
            if due <= now {
                self.delayed.swap_remove(i);
                self.to_send.push_back((id, attempts));
            } else {
                next = Some(next.map_or(due, |n: Instant| n.min(due)));
                i += 1;
            }
        }
        next
    }

    /// Encode fresh requests until the pipelining window is full.
    fn top_up(&mut self, cfg: &LoadGenConfig, info: &ServerInfo,
              window: usize) -> Result<()> {
        let exts = RequestExts { trace: None, priority: cfg.priority };
        while self.inflight.len() < window {
            let Some((id, attempts)) = self.to_send.pop_front() else {
                break;
            };
            let payload =
                make_payload(info, self.seed, id, cfg.spikes,
                             cfg.traffic);
            let req = WireRequest {
                id,
                body: RequestBody::Infer {
                    net: NET_ANY,
                    model: cfg.model.clone(),
                    payload,
                },
            };
            self.out.extend_from_slice(&req.encode_with_exts(&exts)?);
            self.inflight.insert(id, (Instant::now(), attempts));
            self.sent += 1;
        }
        Ok(())
    }

    /// Write queued request bytes until drained or `WouldBlock`.
    fn flush(&mut self) -> io::Result<()> {
        while self.out_pos < self.out.len() {
            match (&self.stream).write(&self.out[self.out_pos..]) {
                Ok(0) => {
                    return Err(io::Error::from(
                        io::ErrorKind::WriteZero));
                }
                Ok(n) => self.out_pos += n,
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    break;
                }
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {
                    continue;
                }
                Err(e) => return Err(e),
            }
        }
        if self.out_pos == self.out.len() {
            self.out.clear();
            self.out_pos = 0;
        }
        Ok(())
    }

    fn into_result(self) -> ConnResult {
        ConnResult {
            sent: self.sent,
            ok: self.ok,
            busy: self.busy,
            degraded: self.degraded,
            errors: self.errors,
            latencies_us: self.latencies_us,
        }
    }
}

/// Drive all `cfg.conns` connections from this one thread with a
/// reactor poll loop. Workload (seeds, splits, windowing, retry
/// policy) is identical to the threaded driver. All connections stay
/// open until every one of them finishes — the server really holds
/// `conns` sockets at once for the whole run.
fn run_mux(cfg: &LoadGenConfig, info: &ServerInfo,
           mut collect: Option<Vec<CollectedResponse>>)
           -> Result<(LoadGenReport, Option<Vec<CollectedResponse>>)> {
    let window = cfg.window.max(1);
    let t0 = Instant::now();
    let mut conns: Vec<MuxConn> = Vec::with_capacity(cfg.conns);
    for i in 0..cfg.conns {
        // Serial blocking connects with one retry: under a c10k burst
        // the kernel may drop SYNs while the accept backlog drains.
        let stream = match TcpStream::connect(&cfg.addr) {
            Ok(s) => s,
            Err(_) => {
                thread::sleep(Duration::from_millis(50));
                TcpStream::connect(&cfg.addr).with_context(
                    || format!("loadgen connect #{i} to {}", cfg.addr))?
            }
        };
        stream.set_nonblocking(true)?;
        let _ = stream.set_nodelay(true);
        conns.push(MuxConn {
            stream,
            recv: RecvBuf::new(),
            out: Vec::new(),
            out_pos: 0,
            to_send: (0..conn_frames(cfg, i) as u64)
                .map(|id| (id, 0)).collect(),
            inflight: HashMap::new(),
            delayed: Vec::new(),
            backoff_rng: SplitMix64::new(
                conn_seed(cfg, i) ^ 0xB0FF_B0FF),
            seed: conn_seed(cfg, i),
            frames: conn_frames(cfg, i) as u64,
            sent: 0,
            ok: 0,
            busy: 0,
            degraded: 0,
            errors: 0,
            latencies_us: Vec::new(),
        });
    }

    let mut fds: Vec<PollFd> = Vec::with_capacity(cfg.conns);
    let mut order: Vec<usize> = Vec::with_capacity(cfg.conns);
    let mut last_progress = Instant::now();
    while conns.iter().any(|c| !c.done()) {
        let now = Instant::now();
        let mut next_deadline: Option<Instant> = None;
        fds.clear();
        order.clear();
        for (i, c) in conns.iter_mut().enumerate() {
            if c.done() {
                continue;
            }
            if let Some(due) = c.release_delayed(now) {
                next_deadline = Some(
                    next_deadline.map_or(due, |d: Instant| d.min(due)));
            }
            c.top_up(cfg, info, window)?;
            let mut ev = 0i16;
            if !c.inflight.is_empty() {
                ev |= POLLIN;
            }
            if c.out_pos < c.out.len() {
                ev |= POLLOUT;
            }
            if ev != 0 {
                fds.push(PollFd::new(reactor::fd_of(&c.stream), ev));
                order.push(i);
            }
        }
        if fds.is_empty() {
            // Nothing pollable: every live connection is waiting out
            // a retry backoff.
            if let Some(d) = next_deadline {
                thread::sleep(d.saturating_duration_since(now)
                              .min(Duration::from_millis(20)));
            }
            continue;
        }
        let timeout = next_deadline
            .map(|d| d.saturating_duration_since(now))
            .unwrap_or(Duration::from_millis(250))
            .min(Duration::from_millis(250));
        let _ = reactor::poll(&mut fds, Some(timeout))?;
        let mut progressed = false;
        for (k, &i) in order.iter().enumerate() {
            let pf = fds[k];
            let c = &mut conns[i];
            if pf.writable() && c.out_pos < c.out.len() {
                c.flush().with_context(
                    || format!("loadgen conn #{i} write"))?;
            }
            if pf.readable() {
                progressed |=
                    mux_read(cfg, i, c, &mut collect).with_context(
                        || format!("loadgen conn #{i}"))?;
            }
        }
        if progressed {
            last_progress = Instant::now();
        } else if last_progress.elapsed() > MUX_STALL_TIMEOUT {
            bail!("loadgen stalled: no response in {:?} ({} conns \
                   unfinished)", MUX_STALL_TIMEOUT,
                  conns.iter().filter(|c| !c.done()).count());
        }
    }
    let wall_secs = t0.elapsed().as_secs_f64();
    let results: Vec<ConnResult> =
        conns.into_iter().map(MuxConn::into_result).collect();
    Ok((aggregate(results, wall_secs, cfg.frames), collect))
}

/// Drain one readable multiplexed connection: fill the receive
/// buffer, decode every complete response frame, apply the same
/// outcome policy as the threaded driver. Returns whether any
/// response landed.
fn mux_read(cfg: &LoadGenConfig, conn_idx: usize, c: &mut MuxConn,
            collect: &mut Option<Vec<CollectedResponse>>)
            -> Result<bool> {
    let mut progressed = false;
    loop {
        match c.recv.fill_from(&mut (&c.stream)) {
            Ok(0) => {
                if c.done() {
                    return Ok(progressed);
                }
                bail!("server closed the connection with {} frames \
                       unfinished", c.frames - c.ok - c.errors);
            }
            Ok(_) => {}
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                return Ok(progressed);
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {
                continue;
            }
            Err(e) => return Err(e.into()),
        }
        loop {
            let (ver, total) =
                match parse_frame(c.recv.data(), KIND_RESPONSE)? {
                    Some(x) => x,
                    None => break,
                };
            let (resp, degrade) = WireResponse::decode_body_ext(
                ver, &c.recv.data()[HEADER_LEN..total])?;
            c.recv.consume(total);
            progressed = true;
            if resp.id == CONN_ERR_ID {
                match resp.body {
                    ResponseBody::Error { code, detail } => {
                        bail!("connection-level {}: {detail}",
                              code.as_str());
                    }
                    other => {
                        bail!("unexpected connection-level response: \
                               {other:?}");
                    }
                }
            }
            let (sent_at, attempts) =
                c.inflight.remove(&resp.id).ok_or_else(
                    || anyhow!("response for unknown id {}", resp.id))?;
            match resp.body {
                ResponseBody::Infer { prediction, output_counts, .. }
                => {
                    c.ok += 1;
                    if degrade.is_some() {
                        c.degraded += 1;
                    }
                    c.latencies_us
                        .push(sent_at.elapsed().as_micros() as u64);
                    if let Some(out) = collect.as_mut() {
                        out.push(CollectedResponse {
                            conn: conn_idx,
                            id: resp.id,
                            prediction,
                            output_counts,
                        });
                    }
                }
                ResponseBody::Error { code: ErrorCode::Busy, .. } => {
                    c.busy += 1;
                    if cfg.retry_busy && attempts < MAX_BUSY_RETRIES {
                        // Same capped jittered curve as the threaded
                        // driver, as a deadline instead of a sleep.
                        let backoff =
                            busy_backoff(&mut c.backoff_rng, attempts);
                        c.delayed.push((Instant::now() + backoff,
                                        resp.id, attempts + 1));
                    } else {
                        c.errors += 1;
                    }
                }
                ResponseBody::Error { .. } => c.errors += 1,
                _ => c.errors += 1,
            }
        }
    }
}
