//! Skydiver wire protocol — versioned, length-prefixed binary frames
//! (std-only, little-endian throughout). Two versions are live:
//! **v1** (single-model, the original format) and **v2** (multi-model:
//! `Infer`/`Info` carry a model selector). A server accepts both and
//! answers each request in the version it arrived with, so old v1
//! clients keep working against a multi-model gateway (their requests
//! route to the registry's *default* model).
//!
//! ## Frame layout
//!
//! ```text
//! +----------+---------+--------+-------------+--------~~--+
//! | magic(4) | ver(1)  | kind(1)| body_len(4) | body       |
//! | "SKYD"   | 1|2     | 1|2    | u32 LE      | body_len B |
//! +----------+---------+--------+-------------+------------+
//! ```
//!
//! `kind` is [`KIND_REQUEST`] or [`KIND_RESPONSE`]. `body_len` is
//! capped at [`MAX_BODY`]; an oversized header is a framing error and
//! the peer disconnects (the stream can no longer be trusted).
//!
//! ## Request body
//!
//! `id: u64`, `op: u8`, then per-op:
//!
//! * `op 0` **Infer** — `net: u8` (0 classifier / 1 segmenter /
//!   [`NET_ANY`] = whatever the routed model runs), **v2 only:**
//!   `model_len: u8` + `model_len` UTF-8 bytes naming the target model
//!   (empty = the server's default model), then `payload_kind: u8`:
//!   `0` pixels (`n: u32`, `n` raw bytes) or `1` pre-encoded spikes
//!   (`timesteps: u32`, `nwords: u32`, `nwords` u64 spike words in
//!   [`SpikeMap`](crate::snn::SpikeMap) packing). A v1 frame has no
//!   selector and routes to the default model.
//! * `op 1` **Metrics** — empty; response is a Prometheus-style
//!   plaintext exposition (per-model series carry a `model` label).
//! * `op 2` **Shutdown** — empty; asks the gateway to drain and exit.
//! * `op 3` **Info** — **v2 only:** `model_len: u8` + name (empty =
//!   default; v1 = empty body = default). Response describes the
//!   selected model (shape + timesteps), so a client can build valid
//!   frames for it.
//! * `op 4` **Heartbeat** — empty, **v2 only** (a v1 frame carrying
//!   it is malformed). Health probe from a cluster router: the
//!   response reports every mounted model's queue-cost depth
//!   ([`ModelLoad`], `coordinator/cost.rs` units) so the router can
//!   place requests on the least-loaded-by-cost backend.
//! * `op 5` **Trace** — empty, **v2 only**. Asks the peer for its
//!   flight-recorder dump (Chrome trace-event JSON of recent /
//!   slowest / errored request traces, see `obs::recorder`).
//!
//! ## Request extensions (v2, `Infer` only)
//!
//! A v2 `Infer` body may carry optional trailing extensions, each
//! `ext_tag: u8` + a tag-determined payload, in any order, at most
//! once each:
//!
//! * [`EXT_TRACE`] — 16-byte trace id + `u64` parent span id
//!   ([`TraceContext`]). The cluster router uses it to stitch its hop
//!   and the backend gateway's spans into one distributed timeline.
//! * [`EXT_PRIORITY`] — `class: u8` scheduling class
//!   (0 high / 1 normal / 2 low, `coordinator::Priority` codes).
//!   Absent = normal. The protocol carries the raw byte; the gateway
//!   rejects unknown classes with `BAD_REQUEST`.
//!
//! Absent extensions = zero extra bytes (the common case is free); an
//! unknown or repeated tag is malformed. [`WireRequest::decode_body`]
//! stays strict (trailing bytes rejected) — extension-aware peers opt
//! in via [`WireRequest::decode_body_ext`]. v1 frames never carry
//! extensions.
//!
//! ## Response extensions (v2, `Infer` only)
//!
//! Symmetrically, a v2 `Infer` *response* may carry trailing
//! extensions; the single tag today is [`EXT_DEGRADE`]
//! ([`DegradeInfo`]): the gateway served this request at reduced
//! timesteps under overload (`t_served < t_full`) and prices the
//! answer (`energy_uj`, the `power/energy.rs` uJ/inference currency)
//! so the caller can weigh the cheaper result. Strict
//! [`WireResponse::decode_body`] rejects it as trailing garbage;
//! degradation-aware clients opt in via
//! [`WireResponse::decode_body_ext`]. v1 responses never carry it
//! (legacy clients see a plain answer).
//!
//! ## Response body
//!
//! `id: u64` (echo), `tag: u8`:
//!
//! * `tag 0` **Infer** — `prediction: u32` (argmax class),
//!   `ncounts: u32`, `ncounts` u32 output spike counts,
//!   `latency_us: u64` (server-side submit→served), `worker: u32`.
//! * `tag 1` **Metrics** — `len: u32`, UTF-8 text.
//! * `tag 2` **ShutdownAck** — empty.
//! * `tag 3` **Error** — `code: u8` ([`ErrorCode`]), `len: u32`,
//!   UTF-8 detail.
//! * `tag 4` **Info** — `net: u8`, `c/h/w/timesteps: u32` each,
//!   **v2 only:** `name_len: u8` + model name, `nmodels: u8` (how many
//!   models the server mounts).
//! * `tag 5` **Heartbeat** — **v2 only:** `nmodels: u8`, then per
//!   model: `name_len: u8` + name, `cost_depth: u64`,
//!   `cost_capacity: u64` (`u64::MAX` = uncapped), `depth: u32`,
//!   `capacity: u32`.
//! * `tag 6` **Trace** — **v2 only:** `len: u32`, UTF-8 JSON (the
//!   flight-recorder dump).
//!
//! Decoding is total: every malformed input returns a typed
//! [`ProtoError`], never panics. [`ProtoError::is_fatal`] separates
//! framing damage (desynced stream → disconnect) from a malformed body
//! inside an intact frame (answerable with `BAD_REQUEST`). Response id
//! [`CONN_ERR_ID`] is reserved for connection-level errors (shed
//! connection, framing damage) — requests must not use it; the gateway
//! rejects an `Infer` carrying it with `BAD_REQUEST`.

use std::io::{self, Read, Write};

use crate::snn::NetKind;

pub const MAGIC: [u8; 4] = *b"SKYD";
/// The original single-model protocol version.
pub const V1: u8 = 1;
/// The multi-model protocol version ([`RequestBody::Infer`]/`Info`
/// carry a model selector).
pub const V2: u8 = 2;
/// The current (preferred) version new clients speak.
pub const VERSION: u8 = V2;
pub const KIND_REQUEST: u8 = 1;
pub const KIND_RESPONSE: u8 = 2;
/// Frame header bytes: magic + version + kind + body_len.
pub const HEADER_LEN: usize = 10;
/// Hard cap on body size (16 MiB) — an oversized header is treated as
/// stream corruption, not an allocation request.
pub const MAX_BODY: usize = 1 << 24;
/// `net` byte meaning "whatever network the routed model runs" — the
/// natural value for a v2 client that addresses models by name. v1
/// clients send a concrete code, which the server checks against the
/// routed model's kind.
pub const NET_ANY: u8 = 0xFF;
/// Reserved response id for *connection-level* errors (shed
/// connection, framing damage, unparsable request id): it can never
/// collide with a request id a well-behaved client chose, so a
/// pipelined client can tell "your request failed" from "this
/// connection failed". Requests must not use it.
pub const CONN_ERR_ID: u64 = u64::MAX;
/// Request-extension tag: trace context (16-byte trace id + u64
/// parent span id) trailing a v2 `Infer` body.
pub const EXT_TRACE: u8 = 1;
/// Request-extension tag: scheduling class (`class: u8`,
/// `coordinator::Priority` codes 0 high / 1 normal / 2 low) trailing
/// a v2 `Infer` body. Absent = normal.
pub const EXT_PRIORITY: u8 = 2;
/// Response-extension tag: degraded-service notice ([`DegradeInfo`])
/// trailing a v2 `Infer` response.
pub const EXT_DEGRADE: u8 = 1;

/// Distributed-tracing context riding a v2 `Infer` request as an
/// optional trailing extension: which trace this request belongs to
/// and which span in the sender's timeline is its parent (0 = none —
/// the receiver's spans become roots of the trace).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceContext {
    pub trace_id: [u8; 16],
    pub parent_span: u64,
}

/// Every optional extension a v2 `Infer` request can carry, parsed
/// (or to be encoded) as one bundle. `Default` = no extensions =
/// byte-identical to a plain [`WireRequest::encode`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct RequestExts {
    /// [`EXT_TRACE`]: distributed-tracing context.
    pub trace: Option<TraceContext>,
    /// [`EXT_PRIORITY`]: raw scheduling-class byte. Carried opaquely;
    /// the gateway maps it via `Priority::from_u8` and answers
    /// `BAD_REQUEST` for unknown codes.
    pub priority: Option<u8>,
}

impl RequestExts {
    /// True when no extension is present (encodes to zero bytes).
    pub fn is_empty(&self) -> bool {
        self.trace.is_none() && self.priority.is_none()
    }
}

/// Degraded-service notice riding a v2 `Infer` response as an
/// optional trailing extension ([`EXT_DEGRADE`]): the gateway chose
/// to serve this request at `t_served < t_full` timesteps instead of
/// shedding it (`--degrade reduce-t`), and `energy_uj` prices the
/// reduced answer in the accelerator's uJ/inference currency so the
/// caller can weigh cost against the accuracy it gave up.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DegradeInfo {
    /// Timesteps actually integrated.
    pub t_served: u32,
    /// The model's configured full-precision timestep count.
    pub t_full: u32,
    /// Estimated energy of the degraded inference, microjoules.
    pub energy_uj: f64,
}

// ---------------------------------------------------------------- errors

/// Typed decode/IO failure.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ProtoError {
    /// Header did not start with [`MAGIC`] — stream desync or a
    /// non-Skydiver peer.
    BadMagic([u8; 4]),
    /// Unknown protocol version.
    BadVersion(u8),
    /// Unknown frame kind byte.
    BadKind(u8),
    /// `body_len` exceeded [`MAX_BODY`].
    Oversized(usize),
    /// The peer closed (or the buffer ended) mid-frame.
    Truncated,
    /// The frame arrived whole but its body does not parse.
    Malformed(String),
    /// A configured read/connect deadline expired mid-operation.
    /// Fatal: a timeout can strike mid-frame, after bytes were
    /// consumed, so the stream position is no longer trustworthy.
    TimedOut,
    /// Underlying socket error.
    Io(String),
}

impl ProtoError {
    /// Fatal errors mean the byte stream can no longer be trusted
    /// (framing lost) — the only safe reaction is to drop the
    /// connection. Non-fatal errors (a malformed body inside a
    /// correctly framed message) can be answered with `BAD_REQUEST`
    /// and the connection kept.
    pub fn is_fatal(&self) -> bool {
        !matches!(self, ProtoError::Malformed(_))
    }
}

impl std::fmt::Display for ProtoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ProtoError::BadMagic(m) => {
                write!(f, "bad magic {m:02x?} (expected \"SKYD\")")
            }
            ProtoError::BadVersion(v) => {
                write!(f, "unsupported protocol version {v}")
            }
            ProtoError::BadKind(k) => write!(f, "unknown frame kind {k}"),
            ProtoError::Oversized(n) => {
                write!(f, "frame body {n} bytes exceeds cap {MAX_BODY}")
            }
            ProtoError::Truncated => write!(f, "truncated frame"),
            ProtoError::Malformed(d) => write!(f, "malformed body: {d}"),
            ProtoError::TimedOut => write!(f, "timed out"),
            ProtoError::Io(e) => write!(f, "io: {e}"),
        }
    }
}

impl std::error::Error for ProtoError {}

/// Typed wire-level error codes carried by `Error` responses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum ErrorCode {
    /// Admission control shed this request (queue full / connection
    /// cap). Retry later.
    Busy = 1,
    /// The request failed validation (wrong payload size, unknown op,
    /// wrong net, unknown model, reserved id, unparsable body).
    BadRequest = 2,
    /// The gateway is draining; no new work is accepted.
    ShuttingDown = 3,
    /// A worker failed while holding this request.
    Internal = 4,
}

impl ErrorCode {
    pub fn from_u8(v: u8) -> Option<Self> {
        Some(match v {
            1 => ErrorCode::Busy,
            2 => ErrorCode::BadRequest,
            3 => ErrorCode::ShuttingDown,
            4 => ErrorCode::Internal,
            _ => return None,
        })
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            ErrorCode::Busy => "BUSY",
            ErrorCode::BadRequest => "BAD_REQUEST",
            ErrorCode::ShuttingDown => "SHUTTING_DOWN",
            ErrorCode::Internal => "INTERNAL",
        }
    }
}

// ------------------------------------------------------------- messages

/// Net kind on the wire.
pub fn net_code(kind: NetKind) -> u8 {
    match kind {
        NetKind::Classifier => 0,
        NetKind::Segmenter => 1,
    }
}

pub fn net_from_code(code: u8) -> Option<NetKind> {
    Some(match code {
        0 => NetKind::Classifier,
        1 => NetKind::Segmenter,
        _ => return None,
    })
}

/// Inference payload as carried on the wire.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WirePayload {
    Pixels(Vec<u8>),
    Spikes { timesteps: u32, words: Vec<u64> },
}

/// Client → server message.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WireRequest {
    pub id: u64,
    pub body: RequestBody,
}

/// `model` is the v2 selector: a model name registered at the gateway,
/// or the empty string for the server's default model. v1 frames decode
/// with an empty `model` (they cannot name one), and a request naming a
/// model is not expressible in v1 ([`WireRequest::encode_v1`] refuses).
/// `Heartbeat` (the cluster health/load probe) and `Trace` (the
/// flight-recorder dump request) are v2-only in both directions.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RequestBody {
    Infer { net: u8, model: String, payload: WirePayload },
    Metrics,
    Shutdown,
    Info { model: String },
    Heartbeat,
    Trace,
}

/// One mounted model's queue occupancy as reported in a `Heartbeat`
/// response — the cost fields are `coordinator/cost.rs` units (the
/// same currency `predict_cost` speaks), so a router can compare load
/// across backends in work, not request counts.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelLoad {
    pub name: String,
    /// Predicted cost of everything currently queued.
    pub cost_depth: u64,
    /// Cost-based admission cap (`u64::MAX` = uncapped).
    pub cost_capacity: u64,
    /// Queue depth in requests.
    pub depth: u32,
    /// Queue capacity in requests.
    pub capacity: u32,
}

/// Server → client message.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WireResponse {
    pub id: u64,
    pub body: ResponseBody,
}

/// `Info.model`/`Info.nmodels` are v2-only fields: a v1 encode drops
/// them, a v1 decode reports the empty name and `nmodels: 1`.
/// `Heartbeat` and `Trace` are v2-only: a v1 frame carrying tag 5 or
/// 6 is malformed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ResponseBody {
    Infer {
        prediction: u32,
        output_counts: Vec<u32>,
        latency_us: u64,
        worker: u32,
    },
    Metrics { text: String },
    ShutdownAck,
    Error { code: ErrorCode, detail: String },
    Info {
        net: u8,
        c: u32,
        h: u32,
        w: u32,
        timesteps: u32,
        model: String,
        nmodels: u8,
    },
    Heartbeat { models: Vec<ModelLoad> },
    Trace { json: String },
}

// -------------------------------------------------------------- encode

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Model names travel as `u8 len + bytes`; longer names cannot be
/// encoded (the registry enforces the same cap at mount time).
pub const MAX_MODEL_NAME: usize = u8::MAX as usize;

fn put_model(out: &mut Vec<u8>, model: &str)
             -> Result<(), ProtoError> {
    if model.len() > MAX_MODEL_NAME {
        return Err(ProtoError::Malformed(format!(
            "model name {} bytes exceeds cap {MAX_MODEL_NAME}",
            model.len())));
    }
    out.push(model.len() as u8);
    out.extend_from_slice(model.as_bytes());
    Ok(())
}

// Note: no size assert here — encode stays infallible; `Client::send`
// rejects over-cap bodies *before* any bytes reach the wire (sending
// one would desync the peer: it reads the header as corruption).
fn frame(version: u8, kind: u8, body: Vec<u8>) -> Vec<u8> {
    let mut out = Vec::with_capacity(HEADER_LEN + body.len());
    out.extend_from_slice(&MAGIC);
    out.push(version);
    out.push(kind);
    put_u32(&mut out, body.len() as u32);
    out.extend_from_slice(&body);
    out
}

impl WireRequest {
    /// Full v2 frame (header + body), ready to write to a socket.
    /// Errors only on an over-long model name ([`MAX_MODEL_NAME`]).
    pub fn encode(&self) -> Result<Vec<u8>, ProtoError> {
        self.encode_with_trace(None)
    }

    /// Full v2 frame with an optional trailing [`TraceContext`]
    /// extension — shorthand for [`WireRequest::encode_with_exts`]
    /// with only the trace slot filled.
    pub fn encode_with_trace(&self, trace: Option<&TraceContext>)
                             -> Result<Vec<u8>, ProtoError> {
        self.encode_with_exts(&RequestExts {
            trace: trace.copied(),
            priority: None,
        })
    }

    /// Full v2 frame with any combination of trailing extensions.
    /// Extensions are only expressible on `Infer` bodies; requesting
    /// one on any other op is an encode error (nothing reaches the
    /// wire). An empty [`RequestExts`] encodes byte-exactly like
    /// [`WireRequest::encode`].
    pub fn encode_with_exts(&self, exts: &RequestExts)
                            -> Result<Vec<u8>, ProtoError> {
        let mut b = Vec::new();
        put_u64(&mut b, self.id);
        match &self.body {
            RequestBody::Infer { net, model, payload } => {
                b.push(0);
                b.push(*net);
                put_model(&mut b, model)?;
                encode_payload(&mut b, payload);
                if let Some(t) = &exts.trace {
                    b.push(EXT_TRACE);
                    b.extend_from_slice(&t.trace_id);
                    put_u64(&mut b, t.parent_span);
                }
                if let Some(p) = exts.priority {
                    b.push(EXT_PRIORITY);
                    b.push(p);
                }
            }
            other => {
                if !exts.is_empty() {
                    return Err(ProtoError::Malformed(format!(
                        "request extensions are only expressible on \
                         Infer, not {other:?}")));
                }
                match other {
                    RequestBody::Infer { .. } => unreachable!(),
                    RequestBody::Metrics => b.push(1),
                    RequestBody::Shutdown => b.push(2),
                    RequestBody::Info { model } => {
                        b.push(3);
                        put_model(&mut b, model)?;
                    }
                    RequestBody::Heartbeat => b.push(4),
                    RequestBody::Trace => b.push(5),
                }
            }
        }
        Ok(frame(V2, KIND_REQUEST, b))
    }

    /// Full **v1** frame — what a legacy client puts on the wire. A
    /// request that names a model is not expressible in v1 and returns
    /// [`ProtoError::Malformed`].
    pub fn encode_v1(&self) -> Result<Vec<u8>, ProtoError> {
        let mut b = Vec::new();
        put_u64(&mut b, self.id);
        match &self.body {
            RequestBody::Infer { net, model, payload } => {
                if !model.is_empty() {
                    return Err(ProtoError::Malformed(format!(
                        "model selector '{model}' is not expressible \
                         in protocol v1")));
                }
                b.push(0);
                b.push(*net);
                encode_payload(&mut b, payload);
            }
            RequestBody::Metrics => b.push(1),
            RequestBody::Shutdown => b.push(2),
            RequestBody::Info { model } => {
                if !model.is_empty() {
                    return Err(ProtoError::Malformed(format!(
                        "model selector '{model}' is not expressible \
                         in protocol v1")));
                }
                b.push(3);
            }
            RequestBody::Heartbeat => {
                return Err(ProtoError::Malformed(
                    "heartbeat requires protocol v2".into()));
            }
            RequestBody::Trace => {
                return Err(ProtoError::Malformed(
                    "trace dump requires protocol v2".into()));
            }
        }
        Ok(frame(V1, KIND_REQUEST, b))
    }

    /// Decode a request body (the bytes after the frame header) at the
    /// version the frame header carried. Strict: trailing extensions
    /// are rejected as trailing garbage — use
    /// [`WireRequest::decode_body_ext`] to accept them.
    pub fn decode_body(version: u8, body: &[u8])
                       -> Result<Self, ProtoError> {
        Self::decode_body_inner(version, body, false)
            .map(|(req, _)| req)
    }

    /// Extension-aware decode: like [`WireRequest::decode_body`] but
    /// a v2 `Infer` body may end with trailing extensions
    /// ([`EXT_TRACE`], [`EXT_PRIORITY`] — any order, at most once
    /// each), returned alongside the request. Extension-free bodies
    /// decode identically in both entry points (an empty
    /// [`RequestExts`] here). v1 frames never carry extensions, so
    /// trailing bytes stay malformed.
    pub fn decode_body_ext(version: u8, body: &[u8])
            -> Result<(Self, RequestExts), ProtoError> {
        Self::decode_body_inner(version, body, true)
    }

    fn decode_body_inner(version: u8, body: &[u8], want_ext: bool)
            -> Result<(Self, RequestExts), ProtoError> {
        let mut r = Cursor::new(body);
        let id = r.u64()?;
        let op = r.u8()?;
        let mut exts = RequestExts::default();
        let body = match op {
            0 => {
                let net = r.u8()?;
                let model = match version {
                    V1 => String::new(),
                    _ => r.model()?,
                };
                let payload = decode_payload(&mut r)?;
                while want_ext && version != V1 && r.remaining() > 0 {
                    match r.u8()? {
                        EXT_TRACE => {
                            if exts.trace.is_some() {
                                return Err(ProtoError::Malformed(
                                    "repeated trace extension".into()));
                            }
                            let mut trace_id = [0u8; 16];
                            trace_id.copy_from_slice(r.bytes(16)?);
                            let parent_span = r.u64()?;
                            exts.trace = Some(TraceContext {
                                trace_id, parent_span,
                            });
                        }
                        EXT_PRIORITY => {
                            if exts.priority.is_some() {
                                return Err(ProtoError::Malformed(
                                    "repeated priority extension"
                                        .into()));
                            }
                            exts.priority = Some(r.u8()?);
                        }
                        tag => {
                            return Err(ProtoError::Malformed(format!(
                                "unknown request extension tag {tag}")))
                        }
                    }
                }
                RequestBody::Infer { net, model, payload }
            }
            1 => RequestBody::Metrics,
            2 => RequestBody::Shutdown,
            3 => {
                let model = match version {
                    V1 => String::new(),
                    _ => r.model()?,
                };
                RequestBody::Info { model }
            }
            4 => {
                if version == V1 {
                    return Err(ProtoError::Malformed(
                        "heartbeat requires protocol v2".into()));
                }
                RequestBody::Heartbeat
            }
            5 => {
                if version == V1 {
                    return Err(ProtoError::Malformed(
                        "trace dump requires protocol v2".into()));
                }
                RequestBody::Trace
            }
            op => {
                return Err(ProtoError::Malformed(format!(
                    "unknown request op {op}")))
            }
        };
        r.finish()?;
        Ok((WireRequest { id, body }, exts))
    }
}

fn encode_payload(b: &mut Vec<u8>, payload: &WirePayload) {
    match payload {
        WirePayload::Pixels(px) => {
            b.push(0);
            put_u32(b, px.len() as u32);
            b.extend_from_slice(px);
        }
        WirePayload::Spikes { timesteps, words } => {
            b.push(1);
            put_u32(b, *timesteps);
            put_u32(b, words.len() as u32);
            for w in words {
                put_u64(b, *w);
            }
        }
    }
}

fn decode_payload(r: &mut Cursor<'_>)
                  -> Result<WirePayload, ProtoError> {
    Ok(match r.u8()? {
        0 => {
            let n = r.u32()? as usize;
            WirePayload::Pixels(r.bytes(n)?.to_vec())
        }
        1 => {
            let timesteps = r.u32()?;
            let n = r.u32()? as usize;
            let raw = r.bytes(n.checked_mul(8).ok_or_else(
                || ProtoError::Malformed(
                    "word count overflow".into()))?)?;
            let words = raw.chunks_exact(8)
                .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
                .collect();
            WirePayload::Spikes { timesteps, words }
        }
        k => {
            return Err(ProtoError::Malformed(format!(
                "unknown payload kind {k}")))
        }
    })
}

impl WireResponse {
    /// Encode at `version` — a server answers each request in the
    /// version it arrived with, so a v1 client never sees a v2 frame.
    /// Only `Info` differs between the versions (the v2-only model
    /// fields are dropped under v1).
    pub fn encode(&self, version: u8) -> Vec<u8> {
        self.encode_with_degrade(version, None)
    }

    /// Encode with an optional trailing [`EXT_DEGRADE`] extension.
    /// The extension only exists on v2 `Infer` responses; on any
    /// other body — or under v1, where the legacy client cannot parse
    /// it — the notice is silently dropped and the frame is
    /// byte-identical to [`WireResponse::encode`]. `degrade: None`
    /// always matches [`WireResponse::encode`] exactly.
    pub fn encode_with_degrade(&self, version: u8,
                               degrade: Option<&DegradeInfo>)
                               -> Vec<u8> {
        let mut b = Vec::new();
        put_u64(&mut b, self.id);
        match &self.body {
            ResponseBody::Infer {
                prediction,
                output_counts,
                latency_us,
                worker,
            } => {
                b.push(0);
                put_u32(&mut b, *prediction);
                put_u32(&mut b, output_counts.len() as u32);
                for c in output_counts {
                    put_u32(&mut b, *c);
                }
                put_u64(&mut b, *latency_us);
                put_u32(&mut b, *worker);
                if version != V1 {
                    if let Some(d) = degrade {
                        b.push(EXT_DEGRADE);
                        put_u32(&mut b, d.t_served);
                        put_u32(&mut b, d.t_full);
                        put_u64(&mut b, d.energy_uj.to_bits());
                    }
                }
            }
            ResponseBody::Metrics { text } => {
                b.push(1);
                put_u32(&mut b, text.len() as u32);
                b.extend_from_slice(text.as_bytes());
            }
            ResponseBody::ShutdownAck => b.push(2),
            ResponseBody::Error { code, detail } => {
                b.push(3);
                b.push(*code as u8);
                put_u32(&mut b, detail.len() as u32);
                b.extend_from_slice(detail.as_bytes());
            }
            ResponseBody::Info {
                net, c, h, w, timesteps, model, nmodels,
            } => {
                b.push(4);
                b.push(*net);
                put_u32(&mut b, *c);
                put_u32(&mut b, *h);
                put_u32(&mut b, *w);
                put_u32(&mut b, *timesteps);
                if version != V1 {
                    // Names come from the registry, which enforces the
                    // wire cap at mount time — an over-long name (only
                    // possible for hand-built responses) degrades to
                    // the empty name rather than a corrupt frame.
                    let name = if model.len() <= MAX_MODEL_NAME {
                        model.as_str()
                    } else {
                        ""
                    };
                    b.push(name.len() as u8);
                    b.extend_from_slice(name.as_bytes());
                    b.push(*nmodels);
                }
            }
            ResponseBody::Heartbeat { models } => {
                // v2-only on the wire; a gateway only emits this in
                // reply to a (v2-only) heartbeat request, so encoding
                // ignores `version`. Registries mount far fewer than
                // 255 models; a hand-built over-long list truncates
                // rather than corrupting the length byte.
                b.push(5);
                let models = &models[..models.len().min(255)];
                b.push(models.len() as u8);
                for m in models {
                    let name = if m.name.len() <= MAX_MODEL_NAME {
                        m.name.as_str()
                    } else {
                        ""
                    };
                    b.push(name.len() as u8);
                    b.extend_from_slice(name.as_bytes());
                    put_u64(&mut b, m.cost_depth);
                    put_u64(&mut b, m.cost_capacity);
                    put_u32(&mut b, m.depth);
                    put_u32(&mut b, m.capacity);
                }
            }
            ResponseBody::Trace { json } => {
                // v2-only on the wire, same reasoning as Heartbeat:
                // only ever sent in reply to a (v2-only) trace
                // request.
                b.push(6);
                put_u32(&mut b, json.len() as u32);
                b.extend_from_slice(json.as_bytes());
            }
        }
        frame(version, KIND_RESPONSE, b)
    }

    /// Strict decode: trailing response extensions are rejected as
    /// trailing garbage — use [`WireResponse::decode_body_ext`] to
    /// accept them.
    pub fn decode_body(version: u8, body: &[u8])
                       -> Result<Self, ProtoError> {
        Self::decode_body_inner(version, body, false)
            .map(|(resp, _)| resp)
    }

    /// Extension-aware decode: like [`WireResponse::decode_body`] but
    /// a v2 `Infer` response may end with an [`EXT_DEGRADE`]
    /// extension, returned alongside. Extension-free bodies decode
    /// identically in both entry points (`None` here). v1 frames
    /// never carry extensions, so trailing bytes stay malformed.
    pub fn decode_body_ext(version: u8, body: &[u8])
            -> Result<(Self, Option<DegradeInfo>), ProtoError> {
        Self::decode_body_inner(version, body, true)
    }

    fn decode_body_inner(version: u8, body: &[u8], want_ext: bool)
            -> Result<(Self, Option<DegradeInfo>), ProtoError> {
        let mut r = Cursor::new(body);
        let id = r.u64()?;
        let tag = r.u8()?;
        let mut degrade = None;
        let body = match tag {
            0 => {
                let prediction = r.u32()?;
                let n = r.u32()? as usize;
                if n > MAX_BODY / 4 {
                    return Err(ProtoError::Malformed(format!(
                        "count vector too long: {n}")));
                }
                let mut output_counts = Vec::with_capacity(n);
                for _ in 0..n {
                    output_counts.push(r.u32()?);
                }
                let latency_us = r.u64()?;
                let worker = r.u32()?;
                while want_ext && version != V1 && r.remaining() > 0 {
                    match r.u8()? {
                        EXT_DEGRADE => {
                            if degrade.is_some() {
                                return Err(ProtoError::Malformed(
                                    "repeated degrade extension"
                                        .into()));
                            }
                            let t_served = r.u32()?;
                            let t_full = r.u32()?;
                            let energy_uj = f64::from_bits(r.u64()?);
                            degrade = Some(DegradeInfo {
                                t_served, t_full, energy_uj,
                            });
                        }
                        tag => {
                            return Err(ProtoError::Malformed(format!(
                                "unknown response extension tag \
                                 {tag}")))
                        }
                    }
                }
                ResponseBody::Infer {
                    prediction,
                    output_counts,
                    latency_us,
                    worker,
                }
            }
            1 => {
                let n = r.u32()? as usize;
                ResponseBody::Metrics { text: r.utf8(n)? }
            }
            2 => ResponseBody::ShutdownAck,
            3 => {
                let code = ErrorCode::from_u8(r.u8()?).ok_or_else(
                    || ProtoError::Malformed("bad error code".into()))?;
                let n = r.u32()? as usize;
                ResponseBody::Error { code, detail: r.utf8(n)? }
            }
            4 => {
                let net = r.u8()?;
                let c = r.u32()?;
                let h = r.u32()?;
                let w = r.u32()?;
                let timesteps = r.u32()?;
                let (model, nmodels) = match version {
                    V1 => (String::new(), 1),
                    _ => (r.model()?, r.u8()?),
                };
                ResponseBody::Info {
                    net, c, h, w, timesteps, model, nmodels,
                }
            }
            5 => {
                if version == V1 {
                    return Err(ProtoError::Malformed(
                        "heartbeat requires protocol v2".into()));
                }
                let n = r.u8()? as usize;
                let mut models = Vec::with_capacity(n);
                for _ in 0..n {
                    let name = r.model()?;
                    let cost_depth = r.u64()?;
                    let cost_capacity = r.u64()?;
                    let depth = r.u32()?;
                    let capacity = r.u32()?;
                    models.push(ModelLoad {
                        name, cost_depth, cost_capacity, depth,
                        capacity,
                    });
                }
                ResponseBody::Heartbeat { models }
            }
            6 => {
                if version == V1 {
                    return Err(ProtoError::Malformed(
                        "trace dump requires protocol v2".into()));
                }
                let n = r.u32()? as usize;
                ResponseBody::Trace { json: r.utf8(n)? }
            }
            tag => {
                return Err(ProtoError::Malformed(format!(
                    "unknown response tag {tag}")))
            }
        };
        r.finish()?;
        Ok((WireResponse { id, body }, degrade))
    }
}

// ------------------------------------------------------------ frame IO

/// Read one frame of the expected kind; returns the frame's protocol
/// version (v1 or v2) alongside its body so the caller can decode —
/// and answer — at the peer's version. `Ok(None)` on clean EOF (the
/// peer closed between frames); [`ProtoError::Truncated`] if the
/// stream ends mid-frame.
pub fn read_frame(r: &mut impl Read, expect_kind: u8)
                  -> Result<Option<(u8, Vec<u8>)>, ProtoError> {
    let mut header = [0u8; HEADER_LEN];
    // First byte separately: 0 bytes here is a clean close, not an
    // error.
    let got = loop {
        match r.read(&mut header[..1]) {
            Ok(n) => break n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(io_err(e)),
        }
    };
    if got == 0 {
        return Ok(None);
    }
    read_exact(r, &mut header[1..])?;
    if header[..4] != MAGIC {
        let mut m = [0u8; 4];
        m.copy_from_slice(&header[..4]);
        return Err(ProtoError::BadMagic(m));
    }
    let version = header[4];
    if version != V1 && version != V2 {
        return Err(ProtoError::BadVersion(version));
    }
    if header[5] != expect_kind {
        return Err(ProtoError::BadKind(header[5]));
    }
    let len = u32::from_le_bytes(header[6..10].try_into().unwrap())
        as usize;
    if len > MAX_BODY {
        return Err(ProtoError::Oversized(len));
    }
    let mut body = vec![0u8; len];
    read_exact(r, &mut body)?;
    Ok(Some((version, body)))
}

/// Incremental, IO-free sibling of [`read_frame`] for nonblocking
/// transports: inspect `buf` (the front of a receive buffer) for one
/// complete frame of the expected kind.
///
/// * `Ok(None)` — not enough bytes yet; read more and call again.
///   Header validation happens as early as the bytes allow (magic is
///   checked from byte 4 on), so a garbage or oversized stream fails
///   fast instead of buffering toward a frame that never completes.
/// * `Ok(Some((version, total_len)))` — `buf[..total_len]` is one
///   whole frame; its body is `buf[HEADER_LEN..total_len]`, to be
///   decoded at `version` and then consumed from the buffer.
/// * `Err(_)` — framing damage, same typed errors as [`read_frame`];
///   the stream is desynced and the connection must drop.
pub fn parse_frame(buf: &[u8], expect_kind: u8)
                   -> Result<Option<(u8, usize)>, ProtoError> {
    if buf.len() >= 4 && buf[..4] != MAGIC {
        let mut m = [0u8; 4];
        m.copy_from_slice(&buf[..4]);
        return Err(ProtoError::BadMagic(m));
    }
    if buf.len() < HEADER_LEN {
        return Ok(None);
    }
    let version = buf[4];
    if version != V1 && version != V2 {
        return Err(ProtoError::BadVersion(version));
    }
    if buf[5] != expect_kind {
        return Err(ProtoError::BadKind(buf[5]));
    }
    let len = u32::from_le_bytes(buf[6..10].try_into().unwrap())
        as usize;
    if len > MAX_BODY {
        return Err(ProtoError::Oversized(len));
    }
    let total = HEADER_LEN + len;
    if buf.len() < total {
        return Ok(None);
    }
    Ok(Some((version, total)))
}

fn read_exact(r: &mut impl Read, buf: &mut [u8])
              -> Result<(), ProtoError> {
    match r.read_exact(buf) {
        Ok(()) => Ok(()),
        Err(e) if e.kind() == io::ErrorKind::UnexpectedEof => {
            Err(ProtoError::Truncated)
        }
        Err(e) => Err(io_err(e)),
    }
}

fn io_err(e: io::Error) -> ProtoError {
    // A socket read deadline fires as `WouldBlock` (unix) or
    // `TimedOut` (windows); both mean "the configured timeout
    // expired", which callers want to tell apart from hard IO damage.
    match e.kind() {
        io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut => {
            ProtoError::TimedOut
        }
        _ => ProtoError::Io(e.to_string()),
    }
}

/// Write one already-encoded frame.
pub fn write_frame(w: &mut impl Write, frame: &[u8]) -> io::Result<()> {
    w.write_all(frame)
}

// -------------------------------------------------------------- cursor

/// Bounds-checked little-endian reader over a body slice.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn bytes(&mut self, n: usize) -> Result<&'a [u8], ProtoError> {
        let end = self.pos.checked_add(n)
            .ok_or(ProtoError::Truncated)?;
        if end > self.buf.len() {
            return Err(ProtoError::Truncated);
        }
        let out = &self.buf[self.pos..end];
        self.pos = end;
        Ok(out)
    }

    fn u8(&mut self) -> Result<u8, ProtoError> {
        Ok(self.bytes(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, ProtoError> {
        Ok(u32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, ProtoError> {
        Ok(u64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }

    fn utf8(&mut self, n: usize) -> Result<String, ProtoError> {
        String::from_utf8(self.bytes(n)?.to_vec()).map_err(|_| {
            ProtoError::Malformed("invalid utf-8".into())
        })
    }

    /// A `u8 len + bytes` model-name selector.
    fn model(&mut self) -> Result<String, ProtoError> {
        let n = self.u8()? as usize;
        self.utf8(n)
    }

    /// Bytes not yet consumed (extension probing).
    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Reject trailing bytes — a well-formed body is consumed exactly.
    fn finish(&self) -> Result<(), ProtoError> {
        if self.pos == self.buf.len() {
            Ok(())
        } else {
            Err(ProtoError::Malformed(format!(
                "{} trailing byte(s)", self.buf.len() - self.pos)))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor as IoCursor;

    fn roundtrip_req(req: WireRequest) {
        let f = req.encode().unwrap();
        let (ver, body) =
            read_frame(&mut IoCursor::new(&f), KIND_REQUEST)
                .unwrap().unwrap();
        assert_eq!(ver, V2);
        assert_eq!(WireRequest::decode_body(ver, &body).unwrap(), req);
    }

    fn roundtrip_resp(resp: WireResponse) {
        for ver in [V1, V2] {
            let f = resp.encode(ver);
            let (got_ver, body) =
                read_frame(&mut IoCursor::new(&f), KIND_RESPONSE)
                    .unwrap().unwrap();
            assert_eq!(got_ver, ver);
            assert_eq!(WireResponse::decode_body(ver, &body).unwrap(),
                       resp);
        }
    }

    #[test]
    fn request_roundtrips() {
        roundtrip_req(WireRequest {
            id: 0,
            body: RequestBody::Infer {
                net: 0,
                model: String::new(),
                payload: WirePayload::Pixels(vec![]),
            },
        });
        roundtrip_req(WireRequest {
            id: u64::MAX,
            body: RequestBody::Infer {
                net: 1,
                model: "segmenter".into(),
                payload: WirePayload::Pixels((0..=255).collect()),
            },
        });
        roundtrip_req(WireRequest {
            id: 7,
            body: RequestBody::Infer {
                net: NET_ANY,
                model: "classifier-v2".into(),
                payload: WirePayload::Spikes {
                    timesteps: 6,
                    words: vec![0, u64::MAX, 0x0123_4567_89AB_CDEF],
                },
            },
        });
        roundtrip_req(WireRequest { id: 1, body: RequestBody::Metrics });
        roundtrip_req(WireRequest { id: 2, body: RequestBody::Shutdown });
        roundtrip_req(WireRequest {
            id: 3,
            body: RequestBody::Info { model: "mnist".into() },
        });
        roundtrip_req(WireRequest {
            id: 4,
            body: RequestBody::Info { model: String::new() },
        });
    }

    #[test]
    fn v1_request_roundtrips() {
        // Model-less requests are expressible in both versions; the v1
        // bytes decode back to the same value (empty model).
        for req in [
            WireRequest {
                id: 5,
                body: RequestBody::Infer {
                    net: 1,
                    model: String::new(),
                    payload: WirePayload::Pixels(vec![1, 2, 3]),
                },
            },
            WireRequest { id: 6, body: RequestBody::Metrics },
            WireRequest { id: 7, body: RequestBody::Shutdown },
            WireRequest {
                id: 8,
                body: RequestBody::Info { model: String::new() },
            },
        ] {
            let f = req.encode_v1().unwrap();
            let (ver, body) =
                read_frame(&mut IoCursor::new(&f), KIND_REQUEST)
                    .unwrap().unwrap();
            assert_eq!(ver, V1);
            assert_eq!(WireRequest::decode_body(ver, &body).unwrap(),
                       req);
        }
    }

    #[test]
    fn model_selector_not_expressible_in_v1() {
        let req = WireRequest {
            id: 9,
            body: RequestBody::Infer {
                net: NET_ANY,
                model: "segmenter".into(),
                payload: WirePayload::Pixels(vec![]),
            },
        };
        assert!(matches!(req.encode_v1(),
                         Err(ProtoError::Malformed(_))));
        let req = WireRequest {
            id: 10,
            body: RequestBody::Info { model: "segmenter".into() },
        };
        assert!(matches!(req.encode_v1(),
                         Err(ProtoError::Malformed(_))));
    }

    #[test]
    fn overlong_model_name_refused_at_encode() {
        let req = WireRequest {
            id: 11,
            body: RequestBody::Info {
                model: "m".repeat(MAX_MODEL_NAME + 1),
            },
        };
        assert!(matches!(req.encode(),
                         Err(ProtoError::Malformed(_))));
    }

    #[test]
    fn response_roundtrips() {
        roundtrip_resp(WireResponse {
            id: 9,
            body: ResponseBody::Infer {
                prediction: 3,
                output_counts: vec![0, 5, 2, 9],
                latency_us: 12345,
                worker: 1,
            },
        });
        roundtrip_resp(WireResponse {
            id: 10,
            body: ResponseBody::Metrics {
                text: "skydiver_up 1\n".into(),
            },
        });
        roundtrip_resp(WireResponse {
            id: 11,
            body: ResponseBody::ShutdownAck,
        });
        roundtrip_resp(WireResponse {
            id: 12,
            body: ResponseBody::Error {
                code: ErrorCode::Busy,
                detail: "queue full (2 entries)".into(),
            },
        });
        // Info only roundtrips across *both* versions when the
        // v2-only fields hold their v1 defaults.
        roundtrip_resp(WireResponse {
            id: 13,
            body: ResponseBody::Info {
                net: 0,
                c: 1,
                h: 28,
                w: 28,
                timesteps: 20,
                model: String::new(),
                nmodels: 1,
            },
        });
    }

    #[test]
    fn v2_info_response_carries_model_fields() {
        let resp = WireResponse {
            id: 14,
            body: ResponseBody::Info {
                net: 1,
                c: 3,
                h: 80,
                w: 160,
                timesteps: 8,
                model: "segmenter".into(),
                nmodels: 2,
            },
        };
        let f = resp.encode(V2);
        let (ver, body) =
            read_frame(&mut IoCursor::new(&f), KIND_RESPONSE)
                .unwrap().unwrap();
        assert_eq!(ver, V2);
        assert_eq!(WireResponse::decode_body(ver, &body).unwrap(),
                   resp);
        // The v1 encoding of the same response drops the model fields.
        let f1 = resp.encode(V1);
        let (ver1, body1) =
            read_frame(&mut IoCursor::new(&f1), KIND_RESPONSE)
                .unwrap().unwrap();
        assert_eq!(ver1, V1);
        match WireResponse::decode_body(ver1, &body1).unwrap().body {
            ResponseBody::Info { model, nmodels, net, .. } => {
                assert_eq!(model, "");
                assert_eq!(nmodels, 1);
                assert_eq!(net, 1);
            }
            other => panic!("unexpected: {other:?}"),
        }
    }

    #[test]
    fn bad_magic_rejected() {
        let mut f = WireRequest {
            id: 1,
            body: RequestBody::Info { model: String::new() },
        }.encode().unwrap();
        f[0] = b'X';
        let err = read_frame(&mut IoCursor::new(&f), KIND_REQUEST)
            .unwrap_err();
        assert!(matches!(err, ProtoError::BadMagic(_)));
        assert!(err.is_fatal());
    }

    #[test]
    fn bad_version_and_kind_rejected() {
        let mut f = WireRequest {
            id: 1,
            body: RequestBody::Info { model: String::new() },
        }.encode().unwrap();
        f[4] = 99;
        assert!(matches!(
            read_frame(&mut IoCursor::new(&f), KIND_REQUEST),
            Err(ProtoError::BadVersion(99))));
        let f = WireRequest {
            id: 1,
            body: RequestBody::Info { model: String::new() },
        }.encode().unwrap();
        assert!(matches!(
            read_frame(&mut IoCursor::new(&f), KIND_RESPONSE),
            Err(ProtoError::BadKind(KIND_REQUEST))));
    }

    #[test]
    fn oversized_length_rejected() {
        let mut f = WireRequest {
            id: 1,
            body: RequestBody::Info { model: String::new() },
        }.encode().unwrap();
        f[6..10].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = read_frame(&mut IoCursor::new(&f), KIND_REQUEST)
            .unwrap_err();
        assert!(matches!(err, ProtoError::Oversized(_)));
        assert!(err.is_fatal());
    }

    #[test]
    fn truncation_never_panics() {
        let f = WireRequest {
            id: 42,
            body: RequestBody::Infer {
                net: 0,
                model: "classifier".into(),
                payload: WirePayload::Pixels(vec![7; 100]),
            },
        }.encode().unwrap();
        // Every proper prefix either reports clean EOF (empty) or a
        // typed error — never a panic, never a bogus success.
        for cut in 0..f.len() {
            let res =
                read_frame(&mut IoCursor::new(&f[..cut]), KIND_REQUEST);
            match res {
                Ok(None) => assert_eq!(cut, 0),
                Ok(Some(_)) => panic!("prefix {cut} decoded as whole"),
                Err(e) => assert!(e.is_fatal() || cut >= HEADER_LEN),
            }
        }
        // Truncated *bodies* (whole frame read, bytes missing inside)
        // are malformed-or-truncated, never a panic.
        let (ver, body) =
            read_frame(&mut IoCursor::new(&f), KIND_REQUEST)
                .unwrap().unwrap();
        for cut in 0..body.len() {
            assert!(WireRequest::decode_body(ver, &body[..cut])
                    .is_err());
        }
    }

    #[test]
    fn trailing_garbage_is_malformed() {
        let f = WireRequest { id: 5, body: RequestBody::Metrics }
            .encode().unwrap();
        let (ver, mut body) =
            read_frame(&mut IoCursor::new(&f), KIND_REQUEST)
                .unwrap().unwrap();
        body.push(0xEE);
        let err = WireRequest::decode_body(ver, &body).unwrap_err();
        assert!(matches!(err, ProtoError::Malformed(_)));
        assert!(!err.is_fatal());
    }

    #[test]
    fn non_utf8_model_name_is_malformed() {
        let req = WireRequest {
            id: 6,
            body: RequestBody::Info { model: "ab".into() },
        };
        let f = req.encode().unwrap();
        let (ver, mut body) =
            read_frame(&mut IoCursor::new(&f), KIND_REQUEST)
                .unwrap().unwrap();
        // Corrupt the selector bytes (after id u64 + op u8 + len u8).
        body[10] = 0xFF;
        body[11] = 0xFE;
        let err = WireRequest::decode_body(ver, &body).unwrap_err();
        assert!(matches!(err, ProtoError::Malformed(_)), "{err}");
        assert!(!err.is_fatal());
    }

    #[test]
    fn parse_frame_incremental_byte_at_a_time() {
        let req = WireRequest {
            id: 42,
            body: RequestBody::Infer {
                net: NET_ANY,
                model: "classifier".into(),
                payload: WirePayload::Pixels(vec![9; 64]),
            },
        };
        let f = req.encode().unwrap();
        // Every proper prefix needs more bytes; the whole frame (and
        // any longer buffer) parses to exactly the frame's length.
        for cut in 0..f.len() {
            assert_eq!(parse_frame(&f[..cut], KIND_REQUEST).unwrap(),
                       None, "prefix {cut} claimed a whole frame");
        }
        let (ver, total) = parse_frame(&f, KIND_REQUEST)
            .unwrap().unwrap();
        assert_eq!((ver, total), (V2, f.len()));
        let decoded =
            WireRequest::decode_body(ver, &f[HEADER_LEN..total])
                .unwrap();
        assert_eq!(decoded, req);
        // Pipelined: a second frame queued behind the first is
        // untouched by the first parse.
        let mut two = f.clone();
        two.extend_from_slice(&f);
        let (_, total) = parse_frame(&two, KIND_REQUEST)
            .unwrap().unwrap();
        assert_eq!(total, f.len());
        assert_eq!(parse_frame(&two[total..], KIND_REQUEST)
                       .unwrap().unwrap().1,
                   f.len());
    }

    #[test]
    fn parse_frame_rejects_damage_like_read_frame() {
        let mut f = WireRequest {
            id: 1,
            body: RequestBody::Info { model: String::new() },
        }.encode().unwrap();
        // Garbage magic fails as soon as 4 bytes exist — even before
        // a full header arrives.
        assert!(matches!(parse_frame(b"XKYD", KIND_REQUEST),
                         Err(ProtoError::BadMagic(_))));
        assert_eq!(parse_frame(b"SKY", KIND_REQUEST).unwrap(), None);
        // Version / kind / length damage match read_frame's verdicts.
        f[4] = 99;
        assert!(matches!(parse_frame(&f, KIND_REQUEST),
                         Err(ProtoError::BadVersion(99))));
        f[4] = V2;
        assert!(matches!(parse_frame(&f, KIND_RESPONSE),
                         Err(ProtoError::BadKind(KIND_REQUEST))));
        f[6..10].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(parse_frame(&f, KIND_REQUEST),
                         Err(ProtoError::Oversized(_))));
    }

    #[test]
    fn heartbeat_request_roundtrips_v2_and_refuses_v1() {
        let req = WireRequest { id: 77, body: RequestBody::Heartbeat };
        let f = req.encode().unwrap();
        let (ver, body) =
            read_frame(&mut IoCursor::new(&f), KIND_REQUEST)
                .unwrap().unwrap();
        assert_eq!(ver, V2);
        assert_eq!(WireRequest::decode_body(ver, &body).unwrap(), req);
        // Not expressible in v1 …
        assert!(matches!(req.encode_v1(),
                         Err(ProtoError::Malformed(_))));
        // … and a hand-built v1 frame carrying op 4 is malformed (but
        // answerable: the frame itself is intact).
        let err = WireRequest::decode_body(V1, &body).unwrap_err();
        assert!(matches!(err, ProtoError::Malformed(_)));
        assert!(!err.is_fatal());
    }

    #[test]
    fn heartbeat_response_roundtrips_v2_only() {
        let resp = WireResponse {
            id: 78,
            body: ResponseBody::Heartbeat {
                models: vec![
                    ModelLoad {
                        name: "classifier".into(),
                        cost_depth: 123_456,
                        cost_capacity: u64::MAX,
                        depth: 3,
                        capacity: 1024,
                    },
                    ModelLoad {
                        name: "segmenter".into(),
                        cost_depth: 0,
                        cost_capacity: 5_000_000,
                        depth: 0,
                        capacity: 64,
                    },
                ],
            },
        };
        let f = resp.encode(V2);
        let (ver, body) =
            read_frame(&mut IoCursor::new(&f), KIND_RESPONSE)
                .unwrap().unwrap();
        assert_eq!(ver, V2);
        assert_eq!(WireResponse::decode_body(ver, &body).unwrap(),
                   resp);
        // A v1 reader cannot decode tag 5.
        let err = WireResponse::decode_body(V1, &body).unwrap_err();
        assert!(matches!(err, ProtoError::Malformed(_)));
        // An empty load list is valid (a backend with nothing
        // mounted still answers probes).
        let empty = WireResponse {
            id: 79,
            body: ResponseBody::Heartbeat { models: vec![] },
        };
        let f = empty.encode(V2);
        let (ver, body) =
            read_frame(&mut IoCursor::new(&f), KIND_RESPONSE)
                .unwrap().unwrap();
        assert_eq!(WireResponse::decode_body(ver, &body).unwrap(),
                   empty);
    }

    #[test]
    fn trace_context_extension_roundtrips() {
        let req = WireRequest {
            id: 21,
            body: RequestBody::Infer {
                net: NET_ANY,
                model: "classifier".into(),
                payload: WirePayload::Pixels(vec![3; 16]),
            },
        };
        let ctx = TraceContext {
            trace_id: *b"0123456789abcdef",
            parent_span: 0xDEAD_BEEF,
        };
        let f = req.encode_with_trace(Some(&ctx)).unwrap();
        let (ver, body) =
            read_frame(&mut IoCursor::new(&f), KIND_REQUEST)
                .unwrap().unwrap();
        assert_eq!(ver, V2);
        let (got, exts) =
            WireRequest::decode_body_ext(ver, &body).unwrap();
        assert_eq!(got, req);
        assert_eq!(exts.trace, Some(ctx));
        assert_eq!(exts.priority, None);
        // The strict decoder sees the extension as trailing garbage
        // (malformed, answerable) — extension awareness is opt-in.
        let err = WireRequest::decode_body(ver, &body).unwrap_err();
        assert!(matches!(err, ProtoError::Malformed(_)));
        assert!(!err.is_fatal());
    }

    #[test]
    fn absent_trace_extension_costs_zero_bytes() {
        let req = WireRequest {
            id: 22,
            body: RequestBody::Infer {
                net: 0,
                model: String::new(),
                payload: WirePayload::Pixels(vec![1, 2]),
            },
        };
        let plain = req.encode().unwrap();
        let untraced = req.encode_with_trace(None).unwrap();
        assert_eq!(plain, untraced);
        // Both decoders agree on an extension-free body.
        let (ver, body) =
            read_frame(&mut IoCursor::new(&plain), KIND_REQUEST)
                .unwrap().unwrap();
        let (got, exts) =
            WireRequest::decode_body_ext(ver, &body).unwrap();
        assert_eq!(got, req);
        assert!(exts.is_empty());
        assert_eq!(WireRequest::decode_body(ver, &body).unwrap(), req);
    }

    #[test]
    fn trace_extension_is_infer_only_and_v2_only() {
        let ctx = TraceContext {
            trace_id: [9; 16],
            parent_span: 1,
        };
        // Encode side: refused on every non-Infer op.
        for body in [RequestBody::Metrics, RequestBody::Shutdown,
                     RequestBody::Info { model: String::new() },
                     RequestBody::Heartbeat, RequestBody::Trace] {
            let req = WireRequest { id: 1, body };
            assert!(matches!(
                req.encode_with_trace(Some(&ctx)),
                Err(ProtoError::Malformed(_))));
        }
        // Decode side: v1 bodies never parse extensions — the same
        // trailing bytes that form a v2 extension are garbage in v1.
        let req = WireRequest {
            id: 2,
            body: RequestBody::Infer {
                net: 0,
                model: String::new(),
                payload: WirePayload::Pixels(vec![7]),
            },
        };
        let f1 = req.encode_v1().unwrap();
        let mut body1 = f1[HEADER_LEN..].to_vec();
        body1.push(EXT_TRACE);
        body1.extend_from_slice(&ctx.trace_id);
        body1.extend_from_slice(&ctx.parent_span.to_le_bytes());
        let err =
            WireRequest::decode_body_ext(V1, &body1).unwrap_err();
        assert!(matches!(err, ProtoError::Malformed(_)));
        assert!(!err.is_fatal());
    }

    #[test]
    fn unknown_or_truncated_extension_is_malformed() {
        let req = WireRequest {
            id: 23,
            body: RequestBody::Infer {
                net: 0,
                model: String::new(),
                payload: WirePayload::Pixels(vec![]),
            },
        };
        let ctx = TraceContext {
            trace_id: [1; 16],
            parent_span: 42,
        };
        let f = req.encode_with_trace(Some(&ctx)).unwrap();
        let body = &f[HEADER_LEN..];
        // Unknown tag.
        let mut doctored = body.to_vec();
        let tag_at = body.len() - 25;
        assert_eq!(doctored[tag_at], EXT_TRACE);
        doctored[tag_at] = 0xEE;
        assert!(matches!(
            WireRequest::decode_body_ext(V2, &doctored),
            Err(ProtoError::Malformed(_))
                | Err(ProtoError::Truncated)));
        // Every truncation of the extension bytes errors, never
        // panics and never parses.
        for cut in tag_at + 1..body.len() {
            assert!(WireRequest::decode_body_ext(V2, &body[..cut])
                .is_err());
        }
        // Trailing bytes *after* a whole extension are still garbage
        // (tag 0 is not a known extension).
        let mut long = body.to_vec();
        long.push(0);
        assert!(matches!(
            WireRequest::decode_body_ext(V2, &long),
            Err(ProtoError::Malformed(_))));
    }

    #[test]
    fn priority_extension_roundtrips_and_composes_with_trace() {
        let req = WireRequest {
            id: 31,
            body: RequestBody::Infer {
                net: NET_ANY,
                model: "classifier".into(),
                payload: WirePayload::Pixels(vec![5; 8]),
            },
        };
        // Priority alone.
        let exts = RequestExts { trace: None, priority: Some(0) };
        let f = req.encode_with_exts(&exts).unwrap();
        let (ver, body) =
            read_frame(&mut IoCursor::new(&f), KIND_REQUEST)
                .unwrap().unwrap();
        let (got, got_exts) =
            WireRequest::decode_body_ext(ver, &body).unwrap();
        assert_eq!(got, req);
        assert_eq!(got_exts, exts);
        // The strict decoder treats it as trailing garbage.
        assert!(matches!(WireRequest::decode_body(ver, &body),
                         Err(ProtoError::Malformed(_))));
        // Both extensions together.
        let both = RequestExts {
            trace: Some(TraceContext {
                trace_id: [7; 16],
                parent_span: 9,
            }),
            priority: Some(2),
        };
        let f = req.encode_with_exts(&both).unwrap();
        let (ver, body) =
            read_frame(&mut IoCursor::new(&f), KIND_REQUEST)
                .unwrap().unwrap();
        let (got, got_exts) =
            WireRequest::decode_body_ext(ver, &body).unwrap();
        assert_eq!(got, req);
        assert_eq!(got_exts, both);
        // An empty bundle is byte-identical to the plain encode.
        assert_eq!(
            req.encode_with_exts(&RequestExts::default()).unwrap(),
            req.encode().unwrap());
    }

    #[test]
    fn extensions_decode_in_any_order_but_never_twice() {
        let req = WireRequest {
            id: 32,
            body: RequestBody::Infer {
                net: 0,
                model: String::new(),
                payload: WirePayload::Pixels(vec![1]),
            },
        };
        let ctx = TraceContext { trace_id: [3; 16], parent_span: 4 };
        // Hand-build priority *before* trace: order-free decode.
        let plain = req.encode().unwrap();
        let mut body = plain[HEADER_LEN..].to_vec();
        body.push(EXT_PRIORITY);
        body.push(1);
        body.push(EXT_TRACE);
        body.extend_from_slice(&ctx.trace_id);
        body.extend_from_slice(&ctx.parent_span.to_le_bytes());
        let (got, exts) =
            WireRequest::decode_body_ext(V2, &body).unwrap();
        assert_eq!(got, req);
        assert_eq!(exts.trace, Some(ctx));
        assert_eq!(exts.priority, Some(1));
        // A repeated tag is malformed, not last-wins.
        let mut dup = plain[HEADER_LEN..].to_vec();
        dup.push(EXT_PRIORITY);
        dup.push(1);
        dup.push(EXT_PRIORITY);
        dup.push(2);
        let err = WireRequest::decode_body_ext(V2, &dup).unwrap_err();
        assert!(matches!(err, ProtoError::Malformed(_)));
        assert!(!err.is_fatal());
        // v1 bodies never parse the priority extension.
        let f1 = req.encode_v1().unwrap();
        let mut body1 = f1[HEADER_LEN..].to_vec();
        body1.push(EXT_PRIORITY);
        body1.push(0);
        assert!(matches!(
            WireRequest::decode_body_ext(V1, &body1),
            Err(ProtoError::Malformed(_))));
    }

    #[test]
    fn degrade_notice_roundtrips_v2_and_vanishes_under_v1() {
        let resp = WireResponse {
            id: 90,
            body: ResponseBody::Infer {
                prediction: 2,
                output_counts: vec![1, 4, 9],
                latency_us: 777,
                worker: 0,
            },
        };
        let info = DegradeInfo {
            t_served: 5,
            t_full: 20,
            energy_uj: 123.5,
        };
        let f = resp.encode_with_degrade(V2, Some(&info));
        let (ver, body) =
            read_frame(&mut IoCursor::new(&f), KIND_RESPONSE)
                .unwrap().unwrap();
        assert_eq!(ver, V2);
        let (got, got_info) =
            WireResponse::decode_body_ext(ver, &body).unwrap();
        assert_eq!(got, resp);
        assert_eq!(got_info, Some(info));
        // The strict decoder rejects the trailing extension.
        assert!(matches!(WireResponse::decode_body(ver, &body),
                         Err(ProtoError::Malformed(_))));
        // Under v1 the notice is dropped: byte-identical to a plain
        // v1 encode, and a legacy decode sees a normal answer.
        assert_eq!(resp.encode_with_degrade(V1, Some(&info)),
                   resp.encode(V1));
        // Absent notice costs zero bytes at v2 too.
        assert_eq!(resp.encode_with_degrade(V2, None),
                   resp.encode(V2));
        // Non-Infer bodies never carry it.
        let err_resp = WireResponse {
            id: 91,
            body: ResponseBody::Error {
                code: ErrorCode::Busy,
                detail: "q".into(),
            },
        };
        assert_eq!(err_resp.encode_with_degrade(V2, Some(&info)),
                   err_resp.encode(V2));
    }

    #[test]
    fn degrade_extension_damage_is_typed_never_panics() {
        let resp = WireResponse {
            id: 92,
            body: ResponseBody::Infer {
                prediction: 0,
                output_counts: vec![],
                latency_us: 1,
                worker: 3,
            },
        };
        let info = DegradeInfo {
            t_served: 1,
            t_full: 8,
            energy_uj: 0.25,
        };
        let f = resp.encode_with_degrade(V2, Some(&info));
        let body = &f[HEADER_LEN..];
        // ext = tag(1) + t_served(4) + t_full(4) + energy(8) = 17 B.
        let tag_at = body.len() - 17;
        assert_eq!(body[tag_at], EXT_DEGRADE);
        // Unknown tag.
        let mut doctored = body.to_vec();
        doctored[tag_at] = 0xEE;
        assert!(WireResponse::decode_body_ext(V2, &doctored).is_err());
        // Every truncation of the extension bytes errors.
        for cut in tag_at + 1..body.len() {
            assert!(WireResponse::decode_body_ext(V2, &body[..cut])
                .is_err());
        }
        // A repeated notice is malformed.
        let mut dup = body.to_vec();
        dup.extend_from_slice(&body[tag_at..]);
        let err = WireResponse::decode_body_ext(V2, &dup).unwrap_err();
        assert!(matches!(err, ProtoError::Malformed(_)));
        // A v1 reader treats the same trailing bytes as garbage.
        assert!(WireResponse::decode_body_ext(V1, body).is_err());
    }

    #[test]
    fn trace_op_roundtrips_v2_and_refuses_v1() {
        let req = WireRequest { id: 80, body: RequestBody::Trace };
        let f = req.encode().unwrap();
        let (ver, body) =
            read_frame(&mut IoCursor::new(&f), KIND_REQUEST)
                .unwrap().unwrap();
        assert_eq!(ver, V2);
        assert_eq!(WireRequest::decode_body(ver, &body).unwrap(), req);
        assert!(matches!(req.encode_v1(),
                         Err(ProtoError::Malformed(_))));
        let err = WireRequest::decode_body(V1, &body).unwrap_err();
        assert!(matches!(err, ProtoError::Malformed(_)));
        assert!(!err.is_fatal());

        let resp = WireResponse {
            id: 80,
            body: ResponseBody::Trace {
                json: "{\"traceEvents\":[]}".into(),
            },
        };
        let f = resp.encode(V2);
        let (ver, body) =
            read_frame(&mut IoCursor::new(&f), KIND_RESPONSE)
                .unwrap().unwrap();
        assert_eq!(ver, V2);
        assert_eq!(WireResponse::decode_body(ver, &body).unwrap(),
                   resp);
        let err = WireResponse::decode_body(V1, &body).unwrap_err();
        assert!(matches!(err, ProtoError::Malformed(_)));
    }

    #[test]
    fn timeout_io_errors_are_typed_and_fatal() {
        for kind in [io::ErrorKind::WouldBlock, io::ErrorKind::TimedOut]
        {
            let err = io_err(io::Error::new(kind, "deadline"));
            assert_eq!(err, ProtoError::TimedOut);
            assert!(err.is_fatal());
        }
        assert!(matches!(
            io_err(io::Error::new(io::ErrorKind::BrokenPipe, "x")),
            ProtoError::Io(_)));
    }

    #[test]
    fn error_codes_roundtrip() {
        for code in [ErrorCode::Busy, ErrorCode::BadRequest,
                     ErrorCode::ShuttingDown, ErrorCode::Internal] {
            assert_eq!(ErrorCode::from_u8(code as u8), Some(code));
        }
        assert_eq!(ErrorCode::from_u8(0), None);
        assert_eq!(ErrorCode::from_u8(200), None);
    }
}
