//! TCP gateway: the network front end of the serving coordinator —
//! registry-routed, multi-model, event-driven.
//!
//! ```text
//! clients ──TCP──> accept loop (poll: listener + waker)
//!                      │ round-robin at accept
//!                      v
//!            ┌─ shard 0 ─┐ ┌─ shard 1 ─┐ … ┌─ shard N-1 ─┐
//!            │ poll loop │ │ poll loop │   │  poll loop  │
//!            │ conn fds  │ │ conn fds  │   │  conn fds   │
//!            │ + waker   │ │ + waker   │   │  + waker    │
//!            └───────────┘ └───────────┘   └─────────────┘
//!              │  per-conn recv buf: incremental frame decode
//!              │  per-conn outbound queue: bounded (write-backpressure)
//!              v  resolve model, validate, try_submit (Full -> BUSY)
//!        [ model 0: Service queue ] <── pull ── workers ┐
//!        [ model 1: Service queue ] <── pull ── workers ┤
//!              │ WorkerEvent                            │
//!              v                                        │
//!        per-model router threads <─────────────────────┘
//!        (match by id) ── frame + self-pipe wake ──> owning shard
//! ```
//!
//! Thread count is **O(shards + models)**, not O(connections): one
//! accept thread, `reactor_shards` event-loop threads (each owning
//! its connections' sockets and buffers), and one router thread per
//! model — thousands of idle or pipelining connections cost fds and
//! buffer bytes, never threads. Routers hand finished responses to
//! the owning shard through its mailbox and wake its `poll` via a
//! self-pipe ([`reactor::Waker`]).
//!
//! Design rules:
//!
//! * **Registry-routed.** Every `Infer`/`Info` resolves its model
//!   selector against the [`ModelRegistry`]: the empty selector (and
//!   every protocol-v1 frame, which cannot carry one) routes to the
//!   default model (registry entry 0); an unknown name is a
//!   `BAD_REQUEST` on that request only.
//! * **Per-model isolation.** Each model owns its queue, worker pool,
//!   stats and admission counters — an overloaded or dead model sheds
//!   or fails *its* traffic while the others keep serving.
//! * **Shed, never hang — and never buffer unboundedly.** Admission
//!   is [`ServiceHandle::try_submit`]; a full queue maps to a `BUSY`
//!   error response immediately. A connection beyond the cap gets one
//!   `BUSY` frame and a close. A connection that stops *reading*
//!   while responses pile up is shed once its outbound queue exceeds
//!   [`GatewayConfig::write_buf_cap`] (counted in
//!   `skydiver_connections_shed_total`) — a stalled reader costs a
//!   bounded buffer, then its connection, never gateway memory.
//! * **Pipelined.** A connection may have any number of requests in
//!   flight; responses carry the request id and may arrive out of
//!   order (different workers finish at different times). Each
//!   response is framed at the protocol version its request arrived
//!   with, so v1 and v2 clients coexist on one gateway.
//! * **Per-request failure.** Malformed bodies get `BAD_REQUEST` on
//!   that request only; framing damage (bad magic, oversized length)
//!   poisons the stream and drops the connection — both without
//!   touching any worker pool. An `Infer` using the reserved
//!   [`CONN_ERR_ID`] is refused with `BAD_REQUEST` — accepting it
//!   would make its response indistinguishable from a
//!   connection-level failure.
//! * **Drain then stop.** Shutdown (wire `Shutdown` message or
//!   [`Gateway::stop_handle`]) stops admission, waits (condvar, not
//!   timer polling) for in-flight requests to finish bounded by
//!   `drain_timeout`, then shuts every model down and flush-closes
//!   every connection.

use std::collections::{HashMap, VecDeque};
use std::io::{self, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::{AutoscaleConfig, AutoscaleObs, Autoscaler,
                         FramePayload, LatencyHistogram, ModelRegistry,
                         PoolScaler, Priority, ReqTrace, ServiceConfig,
                         ServiceHandle, ServingReport, Stats,
                         SubmitError, WorkerConfig, WorkerEvent};
use crate::obs::recorder::{self, TraceMeta};
use crate::obs::trace::{self, Stage};
use crate::{log_error, log_info, log_warn};

use super::protocol::{net_code, parse_frame, DegradeInfo, ErrorCode,
                      ModelLoad, RequestBody, ResponseBody,
                      TraceContext, WirePayload, WireRequest,
                      WireResponse, CONN_ERR_ID, HEADER_LEN,
                      KIND_REQUEST, NET_ANY, V1};
use super::reactor::{self, PollFd, RecvBuf, Waker, POLLIN, POLLOUT};

/// Gateway-level knobs.
#[derive(Debug, Clone)]
pub struct GatewayConfig {
    /// Bind address; port 0 picks an ephemeral port (read it back via
    /// [`Gateway::local_addr`]).
    pub addr: String,
    /// Max simultaneously served connections; one beyond the cap gets
    /// a `BUSY` error frame and an immediate close.
    pub max_conns: usize,
    /// How long shutdown waits for in-flight requests before failing
    /// them with `SHUTTING_DOWN`.
    pub drain_timeout: Duration,
    /// Reactor event-loop shards; connections are assigned
    /// round-robin at accept. `0` = auto: one per core, capped at 8
    /// (beyond that the accept path, not the loops, is the
    /// bottleneck).
    pub reactor_shards: usize,
    /// Per-connection outbound-queue bound in bytes. A connection
    /// whose unread responses exceed this is shed (write
    /// backpressure) instead of buffering without limit.
    pub write_buf_cap: usize,
    /// Worker-pool autoscaling policy, applied to every model whose
    /// pool reserved runtime headroom
    /// (`ServiceConfig::workers_max > workers`). The default
    /// (`min == max`) never spawns the control loop.
    pub autoscale: AutoscaleConfig,
    /// `--degrade reduce-t`: under queue pressure, serve
    /// reduced-timestep inference instead of shedding with `BUSY`.
    /// Only models whose runtime re-parameterizes T per request
    /// participate
    /// ([`degrade_capable`](crate::coordinator::Service::degrade_capable));
    /// their responses carry a [`DegradeInfo`] notice to v2 clients.
    pub degrade_reduce_t: bool,
    /// Floor on the reduced timestep count (`--degrade-floor-t`);
    /// 0 = auto (a quarter of the model's full T, at least 1).
    /// Pressure that would need T below the floor sheds as before.
    pub degrade_floor_t: usize,
}

impl Default for GatewayConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:0".into(),
            max_conns: 64,
            drain_timeout: Duration::from_secs(10),
            reactor_shards: 0,
            write_buf_cap: 8 << 20,
            autoscale: AutoscaleConfig::default(),
            degrade_reduce_t: false,
            degrade_floor_t: 0,
        }
    }
}

impl GatewayConfig {
    /// Resolve `reactor_shards = 0` to the auto shard count.
    fn shards(&self) -> usize {
        if self.reactor_shards > 0 {
            return self.reactor_shards;
        }
        thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
            .clamp(1, 8)
    }
}

/// Monotonic gateway counters (all atomics — readable from any
/// thread, rendered by the `metrics` request).
#[derive(Default)]
struct Counters {
    conns_accepted: AtomicU64,
    conns_active: AtomicU64,
    conns_rejected: AtomicU64,
    conns_shed: AtomicU64,
    requests: AtomicU64,
    served: AtomicU64,
    busy: AtomicU64,
    bad_request: AtomicU64,
    shutting_down: AtomicU64,
    internal: AtomicU64,
}

/// Point-in-time copy of the gateway-wide counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CounterSnapshot {
    pub conns_accepted: u64,
    pub conns_active: u64,
    /// Connections refused at accept (over the connection cap): one
    /// typed `BUSY` frame, then close.
    pub conns_rejected: u64,
    /// Connections shed mid-life by write backpressure (outbound
    /// queue over [`GatewayConfig::write_buf_cap`] because the peer
    /// stopped reading).
    pub conns_shed: u64,
    /// Infer requests admitted to routing (sum over models; excludes
    /// requests refused before a model was resolved, e.g. a reserved
    /// id or an unknown model — those only count as `bad_request`).
    pub requests: u64,
    /// Infer requests answered with a successful prediction.
    pub served: u64,
    /// Requests shed with `BUSY` (queue full).
    pub busy: u64,
    pub bad_request: u64,
    pub shutting_down: u64,
    /// Requests failed because a worker died holding them.
    pub internal: u64,
}

impl Counters {
    fn snapshot(&self) -> CounterSnapshot {
        let ld = |a: &AtomicU64| a.load(Ordering::Relaxed);
        CounterSnapshot {
            conns_accepted: ld(&self.conns_accepted),
            conns_active: ld(&self.conns_active),
            conns_rejected: ld(&self.conns_rejected),
            conns_shed: ld(&self.conns_shed),
            requests: ld(&self.requests),
            served: ld(&self.served),
            busy: ld(&self.busy),
            bad_request: ld(&self.bad_request),
            shutting_down: ld(&self.shutting_down),
            internal: ld(&self.internal),
        }
    }
}

/// Per-model admission/outcome counters (atomics). The `cost_*`
/// counters denominate the same admission flow in predicted-cost
/// units (see `coordinator::cost`), so load and shedding are visible
/// as *work*, not just request count.
#[derive(Default)]
struct ModelCounters {
    requests: AtomicU64,
    served: AtomicU64,
    busy: AtomicU64,
    bad_request: AtomicU64,
    shutting_down: AtomicU64,
    internal: AtomicU64,
    cost_admitted: AtomicU64,
    cost_served: AtomicU64,
    cost_shed: AtomicU64,
    degraded: AtomicU64,
}

/// Point-in-time copy of one model's counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ModelCounterSnapshot {
    /// Infer requests routed to this model (valid or not).
    pub requests: u64,
    pub served: u64,
    pub busy: u64,
    pub bad_request: u64,
    pub shutting_down: u64,
    pub internal: u64,
    /// Predicted cost accepted into this model's queue (cost units).
    pub cost_admitted: u64,
    /// Predicted cost of successfully served responses.
    pub cost_served: u64,
    /// Predicted cost shed with `BUSY` (queue full).
    pub cost_shed: u64,
    /// Served responses that ran at reduced timesteps (a subset of
    /// `served` — degraded, not lost).
    pub degraded: u64,
}

impl ModelCounters {
    fn snapshot(&self) -> ModelCounterSnapshot {
        let ld = |a: &AtomicU64| a.load(Ordering::Relaxed);
        ModelCounterSnapshot {
            requests: ld(&self.requests),
            served: ld(&self.served),
            busy: ld(&self.busy),
            bad_request: ld(&self.bad_request),
            shutting_down: ld(&self.shutting_down),
            internal: ld(&self.internal),
            cost_admitted: ld(&self.cost_admitted),
            cost_served: ld(&self.cost_served),
            cost_shed: ld(&self.cost_shed),
            degraded: ld(&self.degraded),
        }
    }
}

/// One mounted model as the gateway threads see it.
struct ModelRuntime {
    name: String,
    handle: ServiceHandle,
    stats: Mutex<Stats>,
    failures: Mutex<Vec<String>>,
    counters: ModelCounters,
    workers: usize,
    /// Dispatch-mode label of this model's balance metrics.
    dispatch: &'static str,
    /// Interned trace/model index ([`trace::intern_model`]) — span
    /// records and stage histograms carry this instead of the name.
    obs_model: u32,
    /// Pool-resize handle when this model autoscales (`None`: fixed
    /// pool, or autoscaling disabled gateway-wide).
    scaler: Option<PoolScaler>,
    /// Reduced-T floor when degradation applies to this model;
    /// 0 = off (policy off, or a fixed-T runtime).
    degrade_floor: usize,
    /// Scale events applied to this model's pool.
    autoscale_events: AtomicU64,
}

/// Final per-model summary inside a [`GatewayReport`].
#[derive(Debug, Clone)]
pub struct ModelReport {
    pub name: String,
    /// The coordinator-level serving view (latency percentiles from
    /// the bounded histogram, balance, sim FPS/energy).
    pub serving: ServingReport,
    pub counters: ModelCounterSnapshot,
}

/// Final gateway summary returned by [`Gateway::wait`]: gateway-wide
/// counters plus one [`ModelReport`] per mounted model, in registry
/// order (index 0 = the default model).
#[derive(Debug, Clone)]
pub struct GatewayReport {
    pub counters: CounterSnapshot,
    pub models: Vec<ModelReport>,
}

impl GatewayReport {
    /// The default model's report (registry entry 0) — the view v1
    /// single-model callers mean by "the" serving report.
    pub fn default_model(&self) -> &ModelReport {
        &self.models[0]
    }

    pub fn model(&self, name: &str) -> Option<&ModelReport> {
        self.models.iter().find(|m| m.name == name)
    }
}

// ------------------------------------------------------------- transport

/// Where a pending request's response goes: the shard that owns the
/// connection, and the connection's id within the gateway.
#[derive(Debug, Clone, Copy)]
struct ConnRef {
    shard: usize,
    conn: u64,
}

/// Write-span baggage riding an outbound frame: enough to record the
/// reactor-write stage (frame queued on the connection → fully
/// written to the socket) once the last byte leaves. `None` on every
/// frame of an untraced request — the disabled path carries one
/// `Option` discriminant, no allocation.
#[derive(Debug, Clone, Copy)]
struct WriteTrace {
    trace_id: [u8; 16],
    parent: u64,
    model: u32,
    t_queued_ns: u64,
}

/// Work handed to a shard through its mailbox (+ waker).
enum ShardMsg {
    /// A freshly accepted connection to adopt (already counted in
    /// `conns_active`).
    Conn(TcpStream, u64),
    /// A pre-encoded response frame for one of the shard's
    /// connections, produced by a router (or the drain path) on
    /// behalf of a pending request, with optional write-span baggage.
    Frame(u64, Vec<u8>, Option<WriteTrace>),
}

/// One reactor shard's cross-thread face: its mailbox and the waker
/// that interrupts its `poll`.
struct ShardHandle {
    mailbox: Mutex<VecDeque<ShardMsg>>,
    waker: Waker,
    /// Poll-loop wakeups (each poll return counts once).
    wakeups: AtomicU64,
    /// Connections currently owned by this shard.
    connections: AtomicU64,
}

impl ShardHandle {
    fn new() -> io::Result<Self> {
        Ok(Self {
            mailbox: Mutex::new(VecDeque::new()),
            waker: Waker::new()?,
            wakeups: AtomicU64::new(0),
            connections: AtomicU64::new(0),
        })
    }

    fn send(&self, msg: ShardMsg) {
        self.mailbox.lock().unwrap().push_back(msg);
        self.waker.wake();
    }
}

/// One connection as its owning shard sees it. All per-connection
/// state lives here — no per-connection threads, no shared locks on
/// the hot path.
struct Conn {
    stream: TcpStream,
    recv: RecvBuf,
    /// Outbound frames not yet (fully) written; total byte size is
    /// bounded by [`GatewayConfig::write_buf_cap`]. The second slot
    /// is write-span baggage for traced responses.
    out: VecDeque<(Vec<u8>, Option<WriteTrace>)>,
    out_bytes: usize,
    /// How much of `out.front()` has already been written.
    front_pos: usize,
    /// Version the last well-framed request arrived with — the best
    /// guess for framing connection-level errors (defaults to v1,
    /// which every client version decodes).
    peer_ver: u8,
    /// Requests submitted to a model and not yet answered — a
    /// half-closed connection is kept until these flush.
    inflight: usize,
    /// Stop reading, flush `out`, then close (clean EOF, framing
    /// damage after the error frame, wire shutdown ack).
    closing: bool,
    /// Close now; pending output is abandoned (IO error, shed).
    dead: bool,
}

impl Conn {
    fn new(stream: TcpStream) -> Self {
        Self {
            stream,
            recv: RecvBuf::new(),
            out: VecDeque::new(),
            out_bytes: 0,
            front_pos: 0,
            peer_ver: V1,
            inflight: 0,
            closing: false,
            dead: false,
        }
    }
}

struct PendingEntry {
    /// Which shard/connection to answer.
    reply: ConnRef,
    client_id: u64,
    /// Protocol version the request arrived with — its response is
    /// framed the same way.
    version: u8,
    /// Registry slot the request was routed to.
    model: usize,
    /// Trace identity when this request is traced (`None` whenever
    /// tracing was disabled at admission).
    trace: Option<PendingTrace>,
}

/// Trace identity a pending request carries from admission to reply.
#[derive(Debug, Clone, Copy)]
struct PendingTrace {
    trace_id: [u8; 16],
    /// Parent span for this gateway's stage spans (the router's
    /// attempt span in a cluster, 0 standalone).
    parent: u64,
}

/// State shared by the accept loop, shards, and routers.
struct Shared {
    models: Vec<ModelRuntime>,
    /// internal id -> who to answer. Inserted *before* submit so a
    /// response can never race past its route.
    pending: Mutex<HashMap<u64, PendingEntry>>,
    /// Notified when `pending` drains empty (the shutdown path waits
    /// on this instead of sleep-polling).
    pending_cv: Condvar,
    counters: Counters,
    next_id: AtomicU64,
    conn_seq: AtomicU64,
    /// Routers still draining a live worker event stream; the last one
    /// to exit declares the gateway dead (no model can serve).
    live_routers: AtomicUsize,
    /// Drain trigger: stops admission and the accept loop.
    stop: AtomicBool,
    /// Pairs with `stop` for [`Gateway::wait`]'s condvar sleep.
    stop_mu: Mutex<()>,
    stop_cv: Condvar,
    /// Final-phase trigger: shards flush-close their connections and
    /// exit. Set only after pending is drained/failed.
    teardown: AtomicBool,
    /// Interrupts the accept loop's poll (stop requests).
    accept_waker: Waker,
    shards: Vec<ShardHandle>,
    write_buf_cap: usize,
    started: Instant,
}

impl Shared {
    /// Resolve a wire selector: empty = default model (slot 0).
    fn resolve(&self, selector: &str) -> Option<usize> {
        if selector.is_empty() {
            return Some(0);
        }
        self.models.iter().position(|m| m.name == selector)
    }

    /// Hand a response frame to the shard owning `to`'s connection,
    /// with optional write-span baggage for traced requests.
    fn reply(&self, to: ConnRef, frame: Vec<u8>,
             wt: Option<WriteTrace>) {
        self.shards[to.shard]
            .send(ShardMsg::Frame(to.conn, frame, wt));
    }

    /// Remove one pending route, waking the drain waiter when the map
    /// empties.
    fn remove_pending(&self, id: u64) -> Option<PendingEntry> {
        let mut p = self.pending.lock().unwrap();
        let e = p.remove(&id);
        if e.is_some() && p.is_empty() {
            self.pending_cv.notify_all();
        }
        e
    }

    /// Begin drain-then-shutdown: flip the stop flag and wake every
    /// sleeper that gates on it (the [`Gateway::wait`] condvar, the
    /// accept loop's poll). Idempotent.
    fn trigger_stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
        let _g = self.stop_mu.lock().unwrap();
        self.stop_cv.notify_all();
        drop(_g);
        self.accept_waker.wake();
    }
}

/// Remote-controllable drain trigger (cheap clone).
#[derive(Clone)]
pub struct GatewayStop(Arc<Shared>);

impl GatewayStop {
    /// Begin drain-then-shutdown, exactly like a wire `Shutdown`
    /// message.
    pub fn trigger(&self) {
        self.0.trigger_stop();
    }
}

/// A running gateway: a bound listener, its accept loop, N reactor
/// shards, one response router per model, and the owned
/// [`ModelRegistry`].
pub struct Gateway {
    addr: SocketAddr,
    shared: Arc<Shared>,
    registry: ModelRegistry,
    accept: thread::JoinHandle<()>,
    shard_threads: Vec<thread::JoinHandle<()>>,
    routers: Vec<thread::JoinHandle<()>>,
    autoscaler: Option<thread::JoinHandle<()>>,
    drain_timeout: Duration,
}

impl Gateway {
    /// Start from a registry of already-running models, bind, and
    /// begin accepting.
    pub fn start(gcfg: GatewayConfig, mut registry: ModelRegistry)
                 -> Result<Self> {
        let mut runtimes = Vec::with_capacity(registry.len());
        let mut event_streams = Vec::with_capacity(registry.len());
        for idx in 0..registry.len() {
            let entry = registry.entry_mut(idx);
            let events = entry.service_mut().take_events()?;
            let service = entry.service();
            // A model autoscales only when the policy is on AND its
            // pool reserved headroom slots at start.
            let scaler = if gcfg.autoscale.active()
                && service.pool_max() > service.worker_count()
            {
                Some(service.scaler())
            } else {
                None
            };
            let degrade_floor = if gcfg.degrade_reduce_t
                && service.degrade_capable()
            {
                let t = service.frame_spec().timesteps;
                match gcfg.degrade_floor_t {
                    0 => (t / 4).max(1),
                    f => f.clamp(1, t),
                }
            } else {
                0
            };
            runtimes.push(ModelRuntime {
                name: entry.name().to_string(),
                handle: service.handle(),
                stats: Mutex::new(Stats::default()),
                failures: Mutex::new(Vec::new()),
                counters: ModelCounters::default(),
                workers: service.worker_count(),
                dispatch: service.dispatch_mode().as_str(),
                obs_model: trace::intern_model(entry.name()),
                scaler,
                degrade_floor,
                autoscale_events: AtomicU64::new(0),
            });
            event_streams.push(events);
        }
        let listener = TcpListener::bind(&gcfg.addr)
            .with_context(|| format!("binding {}", gcfg.addr))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;

        let nshards = gcfg.shards();
        let mut shards = Vec::with_capacity(nshards);
        for _ in 0..nshards {
            shards.push(ShardHandle::new()
                .context("creating shard waker")?);
        }
        let shared = Arc::new(Shared {
            models: runtimes,
            pending: Mutex::new(HashMap::new()),
            pending_cv: Condvar::new(),
            counters: Counters::default(),
            next_id: AtomicU64::new(1),
            conn_seq: AtomicU64::new(1),
            live_routers: AtomicUsize::new(event_streams.len()),
            stop: AtomicBool::new(false),
            stop_mu: Mutex::new(()),
            stop_cv: Condvar::new(),
            teardown: AtomicBool::new(false),
            accept_waker: Waker::new()
                .context("creating accept waker")?,
            shards,
            write_buf_cap: gcfg.write_buf_cap.max(1024),
            started: Instant::now(),
        });

        let mut routers = Vec::with_capacity(event_streams.len());
        for (idx, events) in event_streams.into_iter().enumerate() {
            let shared = shared.clone();
            routers.push(thread::Builder::new()
                .name(format!("skydiver-router-{idx}"))
                .spawn(move || router_loop(idx, events, shared))?);
        }
        let mut shard_threads = Vec::with_capacity(nshards);
        for idx in 0..nshards {
            let shared = shared.clone();
            shard_threads.push(thread::Builder::new()
                .name(format!("skydiver-shard-{idx}"))
                .spawn(move || shard_loop(idx, shared))?);
        }
        let accept = {
            let shared = shared.clone();
            let max_conns = gcfg.max_conns.max(1);
            thread::Builder::new()
                .name("skydiver-accept".into())
                .spawn(move || {
                    accept_loop(listener, shared, max_conns)
                })?
        };
        let autoscaler = if shared.models.iter()
            .any(|m| m.scaler.is_some())
        {
            let shared = shared.clone();
            let cfg = gcfg.autoscale.clone();
            Some(thread::Builder::new()
                .name("skydiver-autoscale".into())
                .spawn(move || autoscale_loop(cfg, shared))?)
        } else {
            None
        };
        log_info!("server::gateway",
                  "listening on {addr}: {} model(s), {} reactor \
                   shard(s), tracing {}",
                  shared.models.len(), nshards,
                  if trace::enabled() { "on" } else { "off" });

        Ok(Self {
            addr,
            shared,
            registry,
            accept,
            shard_threads,
            routers,
            autoscaler,
            drain_timeout: gcfg.drain_timeout,
        })
    }

    /// Single-model convenience: mount one service under its net's
    /// canonical name ([`NetKind::as_str`](crate::snn::NetKind::as_str))
    /// — the v1 topology as a one-entry registry.
    pub fn start_single(gcfg: GatewayConfig, scfg: ServiceConfig,
                        wcfg: WorkerConfig) -> Result<Self> {
        let name = wcfg.kind.as_str();
        let registry = ModelRegistry::single(name, scfg, wcfg)?;
        Self::start(gcfg, registry)
    }

    /// The actually-bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Mounted model names, registry order (index 0 = default).
    pub fn model_names(&self) -> Vec<&str> {
        self.registry.names()
    }

    /// How many reactor shards this gateway runs.
    pub fn shard_count(&self) -> usize {
        self.shared.shards.len()
    }

    /// A handle that can trigger drain-then-shutdown from any thread.
    pub fn stop_handle(&self) -> GatewayStop {
        GatewayStop(self.shared.clone())
    }

    /// Live gateway-wide counter snapshot (tests / banners).
    pub fn counters(&self) -> CounterSnapshot {
        self.shared.counters.snapshot()
    }

    /// Live counter snapshot for one model (by registry slot).
    pub fn model_counters(&self, idx: usize) -> ModelCounterSnapshot {
        self.shared.models[idx].counters.snapshot()
    }

    /// Block until shutdown is triggered (wire message or
    /// [`Self::stop_handle`]), then drain and tear down. The wait is
    /// a condvar sleep — no polling, wakeup latency is scheduler-
    /// bounded, not timer-quantized.
    pub fn wait(self) -> Result<GatewayReport> {
        {
            let mut g = self.shared.stop_mu.lock().unwrap();
            while !self.shared.stop.load(Ordering::SeqCst) {
                g = self.shared.stop_cv.wait(g).unwrap();
            }
        }
        self.finish()
    }

    /// Trigger shutdown and tear down immediately (still drains).
    pub fn stop_and_wait(self) -> Result<GatewayReport> {
        self.shared.trigger_stop();
        self.finish()
    }

    fn finish(self) -> Result<GatewayReport> {
        let Gateway {
            shared,
            registry,
            accept,
            shard_threads,
            routers,
            autoscaler,
            drain_timeout,
            ..
        } = self;
        // Idempotent: `wait` arrives here with stop already set, but
        // `finish` must also work when called directly.
        shared.trigger_stop();
        let _ = accept.join();
        // The autoscale loop gates on the same stop signal; join it
        // before the registry shutdown so no scale event races a pool
        // teardown.
        if let Some(a) = autoscaler {
            let _ = a.join();
        }
        // Drain: in-flight requests finish as workers catch up (new
        // admissions are already refused with SHUTTING_DOWN). The
        // routers notify `pending_cv` when the map drains empty.
        {
            let guard = shared.pending.lock().unwrap();
            let (guard, timeout) = shared.pending_cv
                .wait_timeout_while(guard, drain_timeout,
                                    |p| !p.is_empty())
                .unwrap();
            if timeout.timed_out() && !guard.is_empty() {
                log_warn!("server::gateway",
                          "drain timeout after {drain_timeout:?}: \
                           failing {} in-flight request(s)",
                          guard.len());
            }
            drop(guard);
        }
        // Whatever outlived the drain window is failed, not stranded.
        {
            let mut pending = shared.pending.lock().unwrap();
            for (_, p) in pending.drain() {
                shared.counters.shutting_down
                    .fetch_add(1, Ordering::Relaxed);
                shared.models[p.model].counters.shutting_down
                    .fetch_add(1, Ordering::Relaxed);
                shared.reply(p.reply, err_frame(
                    p.version, p.client_id, ErrorCode::ShuttingDown,
                    "gateway drain timeout"), None);
            }
        }
        // Close every queue and join workers; their event senders
        // drop, which ends the routers.
        let registry_result = registry.shutdown();
        for r in routers {
            let _ = r.join();
        }
        // Transport teardown: shards flush queued responses (bounded)
        // and close their connections. Joining the shard threads IS
        // the "all connections closed" barrier — no sleep-polling a
        // counter.
        shared.teardown.store(true, Ordering::SeqCst);
        for s in &shared.shards {
            s.waker.wake();
        }
        for t in shard_threads {
            let _ = t.join();
        }

        let wall = shared.started.elapsed().as_secs_f64();
        let models = shared.models.iter().map(|m| {
            let mut serving = m.stats.lock().unwrap().report(
                wall, crate::CLOCK_HZ, m.workers);
            let q = m.handle.queue_stats();
            serving.queue_capacity = q.capacity;
            serving.queue_max_depth = q.max_depth;
            serving.worker_failures =
                m.failures.lock().unwrap().clone();
            ModelReport {
                name: m.name.clone(),
                serving,
                counters: m.counters.snapshot(),
            }
        }).collect();
        let counters = shared.counters.snapshot();
        registry_result?;
        Ok(GatewayReport { counters, models })
    }
}

fn err_resp(id: u64, code: ErrorCode, detail: &str) -> WireResponse {
    WireResponse {
        id,
        body: ResponseBody::Error { code, detail: detail.to_string() },
    }
}

/// Encode an error response at the peer's protocol version.
fn err_frame(version: u8, id: u64, code: ErrorCode, detail: &str)
             -> Vec<u8> {
    err_resp(id, code, detail).encode(version)
}

// --------------------------------------------------------- accept loop

fn accept_loop(listener: TcpListener, shared: Arc<Shared>,
               max_conns: usize) {
    let nshards = shared.shards.len();
    let mut next_shard = 0usize;
    loop {
        if shared.stop.load(Ordering::SeqCst) {
            return;
        }
        let mut fds = [
            PollFd::new(reactor::fd_of(&listener), POLLIN),
            PollFd::new(shared.accept_waker.fd(), POLLIN),
        ];
        let _ = reactor::poll(&mut fds, None);
        if fds[1].readable() {
            shared.accept_waker.drain();
        }
        if shared.stop.load(Ordering::SeqCst) {
            return;
        }
        // Drain the accept backlog; the listener is nonblocking.
        loop {
            match listener.accept() {
                Ok((stream, _peer)) => {
                    shared.counters.conns_accepted
                        .fetch_add(1, Ordering::Relaxed);
                    let active = shared.counters.conns_active
                        .load(Ordering::SeqCst);
                    if active >= max_conns as u64 {
                        shared.counters.conns_rejected
                            .fetch_add(1, Ordering::Relaxed);
                        shed_connection(stream);
                        continue;
                    }
                    shared.counters.conns_active
                        .fetch_add(1, Ordering::SeqCst);
                    let conn_id =
                        shared.conn_seq.fetch_add(1, Ordering::Relaxed);
                    shared.shards[next_shard]
                        .send(ShardMsg::Conn(stream, conn_id));
                    next_shard = (next_shard + 1) % nshards;
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    break;
                }
                Err(e) => {
                    // Transient accept failure (e.g. fd exhaustion):
                    // a brief pause keeps a persistent error from
                    // turning the poll loop hot.
                    log_warn!("server::accept",
                              "accept failed: {e}; pausing 10ms");
                    thread::sleep(Duration::from_millis(10));
                    break;
                }
            }
        }
    }
}

/// Over-cap connection: one typed `BUSY` frame, then close — the
/// client learns *why* instead of seeing a bare RST. Framed at v1 —
/// nothing from the peer has been read yet, and every client version
/// decodes v1 response frames. The freshly accepted socket is
/// blocking (accept does not inherit the listener's nonblocking
/// flag), so the small frame write completes or fails outright.
fn shed_connection(mut stream: TcpStream) {
    let frame = err_frame(V1, CONN_ERR_ID, ErrorCode::Busy,
                          "connection cap reached; retry later");
    let _ = stream.write_all(&frame);
    let _ = stream.flush();
    let _ = stream.shutdown(Shutdown::Both);
}

// --------------------------------------------------------- shard loops

fn shard_loop(idx: usize, shared: Arc<Shared>) {
    let me = &shared.shards[idx];
    let mut conns: HashMap<u64, Conn> = HashMap::new();
    // Rebuilt every iteration; `order[i]` owns `fds[i + 1]` (entry 0
    // is the waker).
    let mut fds: Vec<PollFd> = Vec::new();
    let mut order: Vec<u64> = Vec::new();
    loop {
        fds.clear();
        order.clear();
        fds.push(PollFd::new(me.waker.fd(), POLLIN));
        for (&id, c) in conns.iter() {
            // A closing connection with nothing to write waits only
            // on mailbox frames (in-flight responses) — polling its
            // fd would spin on POLLHUP.
            if c.closing && c.out.is_empty() {
                continue;
            }
            let mut ev = 0i16;
            if !c.closing {
                ev |= POLLIN;
            }
            if !c.out.is_empty() {
                ev |= POLLOUT;
            }
            fds.push(PollFd::new(reactor::fd_of(&c.stream), ev));
            order.push(id);
        }
        let _ = reactor::poll(&mut fds, None);
        me.wakeups.fetch_add(1, Ordering::Relaxed);
        if fds[0].readable() {
            me.waker.drain();
        }
        if shared.teardown.load(Ordering::SeqCst) {
            shard_teardown(&shared, me, &mut conns);
            return;
        }
        // Mailbox: adopt new connections, route response frames.
        let msgs: VecDeque<ShardMsg> =
            std::mem::take(&mut *me.mailbox.lock().unwrap());
        for msg in msgs {
            match msg {
                ShardMsg::Conn(stream, id) => {
                    if stream.set_nonblocking(true).is_err() {
                        shared.counters.conns_active
                            .fetch_sub(1, Ordering::SeqCst);
                        continue;
                    }
                    let _ = stream.set_nodelay(true);
                    me.connections.fetch_add(1, Ordering::Relaxed);
                    conns.insert(id, Conn::new(stream));
                }
                ShardMsg::Frame(id, frame, wt) => {
                    if let Some(c) = conns.get_mut(&id) {
                        c.inflight = c.inflight.saturating_sub(1);
                        push_frame(&shared, c, frame, wt);
                    }
                    // else: the connection died first; the response
                    // has nowhere to go.
                }
            }
        }
        // Reads: decode and handle every complete frame available.
        for (i, &id) in order.iter().enumerate() {
            let pf = fds[i + 1];
            if !pf.readable() {
                continue;
            }
            if let Some(c) = conns.get_mut(&id) {
                if !c.dead && !c.closing {
                    service_read(&shared, idx, id, c);
                }
            }
        }
        // Writes: opportunistic flush of everything queued (new
        // frames this round included — most sockets are writable, so
        // this usually clears without waiting for POLLOUT).
        for c in conns.values_mut() {
            if !c.dead && !c.out.is_empty() && flush_out(c).is_err() {
                c.dead = true;
            }
        }
        // Reap: dead now, or closing with nothing left to deliver.
        let finished: Vec<u64> = conns.iter()
            .filter(|(_, c)| {
                c.dead
                    || (c.closing && c.out.is_empty()
                        && c.inflight == 0)
            })
            .map(|(&id, _)| id)
            .collect();
        for id in finished {
            let c = conns.remove(&id).unwrap();
            let _ = c.stream.shutdown(Shutdown::Both);
            me.connections.fetch_sub(1, Ordering::Relaxed);
            shared.counters.conns_active
                .fetch_sub(1, Ordering::SeqCst);
        }
    }
}

/// Final transport teardown: deliver what the mailbox still holds,
/// give each connection one bounded blocking flush, close everything.
fn shard_teardown(shared: &Arc<Shared>, me: &ShardHandle,
                  conns: &mut HashMap<u64, Conn>) {
    let msgs: VecDeque<ShardMsg> =
        std::mem::take(&mut *me.mailbox.lock().unwrap());
    for msg in msgs {
        match msg {
            ShardMsg::Conn(stream, _) => {
                // Accepted but never served: count it back out.
                let _ = stream.shutdown(Shutdown::Both);
                shared.counters.conns_active
                    .fetch_sub(1, Ordering::SeqCst);
            }
            ShardMsg::Frame(id, frame, _) => {
                // Teardown delivery drops span baggage: the process
                // is exiting, nothing will dump these.
                if let Some(c) = conns.get_mut(&id) {
                    c.out_bytes += frame.len();
                    c.out.push_back((frame, None));
                }
            }
        }
    }
    for (_, c) in conns.drain() {
        final_flush_close(c);
        me.connections.fetch_sub(1, Ordering::Relaxed);
        shared.counters.conns_active.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Bounded best-effort delivery of a closing connection's queued
/// frames (shutdown acks, drain-timeout errors), then close.
fn final_flush_close(mut c: Conn) {
    if !c.dead && !c.out.is_empty() {
        let _ = c.stream.set_nonblocking(false);
        let _ = c.stream.set_write_timeout(
            Some(Duration::from_millis(500)));
        while let Some((front, _)) = c.out.front() {
            match (&c.stream).write(&front[c.front_pos..]) {
                Ok(0) => break,
                Ok(n) => {
                    c.front_pos += n;
                    if c.front_pos == front.len() {
                        c.out.pop_front();
                        c.front_pos = 0;
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {
                    continue;
                }
                Err(_) => break,
            }
        }
    }
    let _ = c.stream.shutdown(Shutdown::Both);
}

/// Queue one outbound frame, enforcing the per-connection write
/// bound. Over the cap the connection is shed: a best-effort typed
/// notice goes straight to the socket (usually undeliverable — the
/// peer is not reading — and never queued) and the connection dies.
fn push_frame(shared: &Shared, c: &mut Conn, frame: Vec<u8>,
              wt: Option<WriteTrace>) {
    if c.dead {
        return;
    }
    if c.out_bytes + frame.len() > shared.write_buf_cap {
        shared.counters.conns_shed.fetch_add(1, Ordering::Relaxed);
        log_warn!("server::reactor",
                  "shedding connection: outbound queue {} bytes \
                   over cap {}", c.out_bytes + frame.len(),
                  shared.write_buf_cap);
        let note = err_frame(
            c.peer_ver, CONN_ERR_ID, ErrorCode::Busy,
            "write backpressure: outbound queue over cap; \
             connection shed");
        let _ = (&c.stream).write_all(&note);
        c.dead = true;
        return;
    }
    c.out_bytes += frame.len();
    c.out.push_back((frame, wt));
}

/// Write queued frames until done or the socket would block.
fn flush_out(c: &mut Conn) -> io::Result<()> {
    while let Some((front, wt)) = c.out.front() {
        match (&c.stream).write(&front[c.front_pos..]) {
            Ok(0) => {
                return Err(io::Error::from(
                    io::ErrorKind::WriteZero));
            }
            Ok(n) => {
                c.front_pos += n;
                c.out_bytes -= n;
                if c.front_pos == front.len() {
                    // Traced frame fully on the wire: close its
                    // reactor-write span (queued -> last byte out).
                    if let Some(wt) = wt {
                        trace::span(wt.trace_id, wt.parent,
                                    Stage::Write, wt.model,
                                    wt.t_queued_ns, false,
                                    front.len() as u64, 0);
                    }
                    c.out.pop_front();
                    c.front_pos = 0;
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                return Ok(());
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {
                continue;
            }
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

/// Drain the socket's readable bytes into the receive buffer and
/// handle every complete frame. Leaves no complete frame unparsed —
/// the next poll round only needs to fire for *new* bytes.
fn service_read(shared: &Arc<Shared>, shard: usize, conn_id: u64,
                c: &mut Conn) {
    loop {
        match c.recv.fill_from(&mut (&c.stream)) {
            Ok(0) => {
                // Clean EOF. In-flight responses still flush (the
                // peer may have half-closed); then the reap check
                // closes us.
                c.closing = true;
                break;
            }
            Ok(_) => {
                decode_frames(shared, shard, conn_id, c);
                if c.dead || c.closing {
                    return;
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {
                continue;
            }
            Err(_) => {
                c.dead = true;
                return;
            }
        }
    }
    if !c.recv.is_empty() && c.closing {
        // EOF mid-frame: the partial frame can never complete. Only
        // this connection is affected; its in-flight requests die
        // with it (their responses find no connection to land on).
        c.dead = true;
    }
}

/// Parse and dispatch every complete frame in the receive buffer.
fn decode_frames(shared: &Arc<Shared>, shard: usize, conn_id: u64,
                 c: &mut Conn) {
    loop {
        match parse_frame(c.recv.data(), KIND_REQUEST) {
            Ok(Some((ver, total))) => {
                let body = c.recv.data()[HEADER_LEN..total].to_vec();
                c.recv.consume(total);
                c.peer_ver = ver;
                on_request(shared, shard, conn_id, c, ver, &body);
                if c.dead || c.closing {
                    return;
                }
            }
            Ok(None) => return,
            Err(e) => {
                // Framing damage: the stream is desynced. Answer once
                // (best effort) so the peer learns why, then drop.
                shared.counters.bad_request
                    .fetch_add(1, Ordering::Relaxed);
                let f = err_frame(c.peer_ver, CONN_ERR_ID,
                                  ErrorCode::BadRequest,
                                  &e.to_string());
                push_frame(shared, c, f, None);
                c.closing = true;
                return;
            }
        }
    }
}

/// Handle one well-framed request arriving on a shard connection.
fn on_request(shared: &Arc<Shared>, shard: usize, conn_id: u64,
              c: &mut Conn, ver: u8, body: &[u8]) {
    let (req, exts) =
        match WireRequest::decode_body_ext(ver, body) {
        Ok(pair) => pair,
        Err(e) => {
            // The frame boundary held: reject this request, keep
            // the connection. The request id may not have parsed,
            // so answer on the reserved connection-error id.
            shared.counters.bad_request
                .fetch_add(1, Ordering::Relaxed);
            let f = err_frame(ver, CONN_ERR_ID, ErrorCode::BadRequest,
                              &e.to_string());
            push_frame(shared, c, f, None);
            return;
        }
    };
    // The reserved id cannot name a request: its response would be
    // indistinguishable from a connection-level failure.
    if req.id == CONN_ERR_ID {
        shared.counters.bad_request.fetch_add(1, Ordering::Relaxed);
        let f = err_frame(
            ver, CONN_ERR_ID, ErrorCode::BadRequest,
            &format!("request id {CONN_ERR_ID} is reserved for \
                      connection-level errors"));
        push_frame(shared, c, f, None);
        return;
    }
    match req.body {
        RequestBody::Infer { net, model, payload } => {
            // When tracing is on, every admitted request gets a trace
            // identity: the wire context when the peer (a cluster
            // router) sent one, a fresh root otherwise. When off, no
            // timestamps are taken and nothing allocates.
            let ctx = if trace::enabled() {
                Some(exts.trace.unwrap_or(TraceContext {
                    trace_id: trace::gen_trace_id(),
                    parent_span: 0,
                }))
            } else {
                None
            };
            // An unknown priority byte is a per-request error, not a
            // silent default: the class changes scheduling, so a
            // client must learn its byte meant nothing.
            let pri = match exts.priority.map(Priority::from_u8) {
                None => Priority::Normal,
                Some(Some(p)) => p,
                Some(None) => {
                    shared.counters.bad_request
                        .fetch_add(1, Ordering::Relaxed);
                    let f = err_frame(
                        ver, req.id, ErrorCode::BadRequest,
                        &format!("unknown priority class {} (known: \
                                  0=high 1=normal 2=low)",
                                 exts.priority.unwrap_or(0)));
                    push_frame(shared, c, f, None);
                    return;
                }
            };
            handle_infer(shared, shard, conn_id, c, ver, req.id, net,
                         &model, payload, ctx, pri);
        }
        RequestBody::Metrics => {
            let text = render_metrics(shared);
            let f = WireResponse {
                id: req.id,
                body: ResponseBody::Metrics { text },
            }.encode(ver);
            push_frame(shared, c, f, None);
        }
        RequestBody::Info { model } => {
            let resp = match shared.resolve(&model) {
                None => err_resp(req.id, ErrorCode::BadRequest,
                                 &unknown_model(shared, &model)),
                Some(idx) => {
                    let m = &shared.models[idx];
                    let s = m.handle.spec();
                    WireResponse {
                        id: req.id,
                        body: ResponseBody::Info {
                            net: net_code(s.kind),
                            c: s.c as u32,
                            h: s.h as u32,
                            w: s.w as u32,
                            timesteps: s.timesteps as u32,
                            model: m.name.clone(),
                            nmodels: shared.models.len() as u8,
                        },
                    }
                }
            };
            push_frame(shared, c, resp.encode(ver), None);
        }
        RequestBody::Shutdown => {
            let f = WireResponse {
                id: req.id,
                body: ResponseBody::ShutdownAck,
            }.encode(ver);
            push_frame(shared, c, f, None);
            shared.trigger_stop();
        }
        RequestBody::Heartbeat => {
            // Health/load probe from a cluster router: answer from
            // the queues alone (no worker involvement), so a wedged
            // worker slows inference, not health reporting.
            let models = shared.models.iter().map(|m| {
                let q = m.handle.queue_stats();
                ModelLoad {
                    name: m.name.clone(),
                    cost_depth: q.cost_depth,
                    cost_capacity: q.cost_capacity,
                    depth: q.depth as u32,
                    capacity: q.capacity as u32,
                }
            }).collect();
            let f = WireResponse {
                id: req.id,
                body: ResponseBody::Heartbeat { models },
            }.encode(ver);
            push_frame(shared, c, f, None);
        }
        RequestBody::Trace => {
            // Flight-recorder dump: the retained traces' spans as
            // Chrome trace-event JSON (empty event list when tracing
            // is disabled).
            let f = WireResponse {
                id: req.id,
                body: ResponseBody::Trace {
                    json: recorder::dump_chrome_json(),
                },
            }.encode(ver);
            push_frame(shared, c, f, None);
        }
    }
}

fn unknown_model(shared: &Shared, selector: &str) -> String {
    let names: Vec<&str> =
        shared.models.iter().map(|m| m.name.as_str()).collect();
    format!("unknown model '{selector}'; mounted: [{}] (empty selector \
             = default '{}')", names.join(", "), names[0])
}

#[allow(clippy::too_many_arguments)]
fn handle_infer(shared: &Arc<Shared>, shard: usize, conn_id: u64,
                c: &mut Conn, version: u8, client_id: u64, net: u8,
                model: &str, payload: WirePayload,
                ctx: Option<TraceContext>, pri: Priority) {
    // `ctx` is Some only when tracing is enabled, so the disabled
    // path never reads the clock.
    let t_admit = if ctx.is_some() { trace::now_ns() } else { 0 };
    let idx = match shared.resolve(model) {
        Some(idx) => idx,
        None => {
            shared.counters.bad_request.fetch_add(1, Ordering::Relaxed);
            let f = err_frame(version, client_id, ErrorCode::BadRequest,
                              &unknown_model(shared, model));
            push_frame(shared, c, f, None);
            return;
        }
    };
    let m = &shared.models[idx];
    shared.counters.requests.fetch_add(1, Ordering::Relaxed);
    m.counters.requests.fetch_add(1, Ordering::Relaxed);
    if shared.stop.load(Ordering::SeqCst) {
        shared.counters.shutting_down.fetch_add(1, Ordering::Relaxed);
        m.counters.shutting_down.fetch_add(1, Ordering::Relaxed);
        let f = err_frame(version, client_id, ErrorCode::ShuttingDown,
                          "gateway is draining");
        push_frame(shared, c, f, None);
        return;
    }
    let spec = m.handle.spec();
    // v1 clients address by net code; check it against the routed
    // model so a misdirected request fails loudly instead of running
    // through the wrong network. NET_ANY (the v2 idiom) skips this.
    if net != NET_ANY && net != net_code(spec.kind) {
        shared.counters.bad_request.fetch_add(1, Ordering::Relaxed);
        m.counters.bad_request.fetch_add(1, Ordering::Relaxed);
        let f = err_frame(
            version, client_id, ErrorCode::BadRequest,
            &format!("model '{}' runs net {:?}, request asked for \
                      code {net}", m.name, spec.kind));
        push_frame(shared, c, f, None);
        return;
    }
    let payload = match payload {
        WirePayload::Pixels(px) => FramePayload::Pixels(px),
        WirePayload::Spikes { timesteps, words } => {
            FramePayload::Spikes { timesteps: timesteps as usize, words }
        }
    };
    // Validate against the model's frame contract *here*: a malformed
    // request costs one response, never a worker.
    if let Err(detail) = spec.validate(&payload) {
        shared.counters.bad_request.fetch_add(1, Ordering::Relaxed);
        m.counters.bad_request.fetch_add(1, Ordering::Relaxed);
        let f = err_frame(version, client_id, ErrorCode::BadRequest,
                          &detail);
        push_frame(shared, c, f, None);
        return;
    }
    // Admission span: frame decoded -> model resolved + contract
    // validated.
    if let Some(cx) = ctx {
        trace::span(cx.trace_id, cx.parent_span, Stage::Admission,
                    m.obs_model, t_admit, false, 0, 0);
    }
    // Request-level APRC: predict once, tag admission with it, and
    // account the admitted/shed flow in cost units alongside counts.
    let t_cp = if ctx.is_some() { trace::now_ns() } else { 0 };
    let cost = m.handle.predict_cost(&payload);
    if let Some(cx) = ctx {
        trace::span(cx.trace_id, cx.parent_span, Stage::CostPredict,
                    m.obs_model, t_cp, false, cost, 0);
    }
    // Graceful degradation: under queue pressure, serve *fewer
    // timesteps* instead of shedding. Pressure is the max of this
    // model's count- and cost-fraction; from 50% full the served T
    // ramps linearly from full down to the model's floor, and only
    // traffic the floor can't absorb is shed (by the queue, with
    // `BUSY`, as before).
    let mut degrade_t = None;
    let mut cost = cost;
    if m.degrade_floor > 0 && m.degrade_floor < spec.timesteps {
        let q = m.handle.queue_stats();
        let mut p = q.depth as f64 / q.capacity.max(1) as f64;
        if q.cost_capacity != u64::MAX && q.cost_capacity > 0 {
            p = p.max(q.cost_depth as f64 / q.cost_capacity as f64);
        }
        if p > 0.5 {
            let t_full = spec.timesteps;
            let frac = ((p - 0.5) / 0.5).min(1.0);
            let span = (t_full - m.degrade_floor) as f64;
            let t_eff = t_full - (span * frac).round() as usize;
            if t_eff < t_full {
                degrade_t = Some(t_eff);
                // The admission tag shrinks with the work: a degraded
                // frame integrates t_eff/t_full of the timesteps.
                cost = (cost.saturating_mul(t_eff as u64)
                        / t_full as u64).max(1);
            }
        }
    }
    let internal = shared.next_id.fetch_add(1, Ordering::Relaxed);
    shared.pending.lock().unwrap().insert(internal, PendingEntry {
        reply: ConnRef { shard, conn: conn_id },
        client_id,
        version,
        model: idx,
        trace: ctx.map(|cx| PendingTrace {
            trace_id: cx.trace_id,
            parent: cx.parent_span,
        }),
    });
    c.inflight += 1;
    let rt = ctx.map(|cx| ReqTrace {
        trace_id: cx.trace_id,
        parent: cx.parent_span,
        t_enqueue_ns: trace::now_ns(),
        model: m.obs_model,
    });
    match m.handle.try_submit_full(internal, payload, cost, rt, pri,
                                   degrade_t) {
        Ok(()) => {
            m.counters.cost_admitted.fetch_add(cost, Ordering::Relaxed);
        }
        Err(e) => {
            shared.remove_pending(internal);
            c.inflight = c.inflight.saturating_sub(1);
            if let Some(cx) = ctx {
                recorder::complete(TraceMeta {
                    trace_id: cx.trace_id,
                    model: m.obs_model,
                    latency_us: 0,
                    error: true,
                });
            }
            let code = match e {
                SubmitError::Full { .. } => {
                    shared.counters.busy.fetch_add(1, Ordering::Relaxed);
                    m.counters.busy.fetch_add(1, Ordering::Relaxed);
                    m.counters.cost_shed
                        .fetch_add(cost, Ordering::Relaxed);
                    ErrorCode::Busy
                }
                SubmitError::Closed | SubmitError::NoWorkers => {
                    shared.counters.shutting_down
                        .fetch_add(1, Ordering::Relaxed);
                    m.counters.shutting_down
                        .fetch_add(1, Ordering::Relaxed);
                    ErrorCode::ShuttingDown
                }
            };
            let f = err_frame(version, client_id, code,
                              &e.to_string());
            push_frame(shared, c, f, None);
        }
    }
}

// -------------------------------------------------------------- router

/// Owns one model's worker event stream: matches responses back to
/// their connection by internal id, folds that model's serving stats,
/// and fails exactly the requests a dying worker had in hand.
/// Delivery is a mailbox push + waker to the shard owning the
/// connection — routers never touch sockets.
fn router_loop(model_idx: usize,
               events: mpsc::Receiver<WorkerEvent>,
               shared: Arc<Shared>) {
    let m = &shared.models[model_idx];
    while let Ok(ev) = events.recv() {
        match ev {
            WorkerEvent::Served(r) => {
                m.stats.lock().unwrap().record(&r);
                shared.counters.served.fetch_add(1, Ordering::Relaxed);
                m.counters.served.fetch_add(1, Ordering::Relaxed);
                m.counters.cost_served
                    .fetch_add(r.predicted_cost, Ordering::Relaxed);
                if r.degraded {
                    m.counters.degraded.fetch_add(1, Ordering::Relaxed);
                }
                if let Some(p) = shared.remove_pending(r.id) {
                    let prediction = r.output_counts.iter().enumerate()
                        .max_by_key(|&(_, c)| *c)
                        .map(|(i, _)| i as u32)
                        .unwrap_or(0);
                    let t_enc = if p.trace.is_some() {
                        trace::now_ns()
                    } else {
                        0
                    };
                    // Degraded responses tell the client what fidelity
                    // it got and what it cost; the notice silently
                    // vanishes for v1 peers (they asked before the
                    // extension existed).
                    let degrade = if r.degraded {
                        Some(DegradeInfo {
                            t_served: r.timesteps,
                            t_full: m.handle.spec().timesteps as u32,
                            energy_uj: r.energy_j * 1e6,
                        })
                    } else {
                        None
                    };
                    let frame = WireResponse {
                        id: p.client_id,
                        body: ResponseBody::Infer {
                            prediction,
                            output_counts: r.output_counts,
                            latency_us: r.latency_us,
                            worker: r.worker as u32,
                        },
                    }.encode_with_degrade(p.version, degrade.as_ref());
                    let wt = p.trace.map(|t| {
                        trace::span(t.trace_id, t.parent,
                                    Stage::Encode, m.obs_model,
                                    t_enc, false,
                                    frame.len() as u64, 0);
                        recorder::complete(TraceMeta {
                            trace_id: t.trace_id,
                            model: m.obs_model,
                            latency_us: r.latency_us,
                            error: false,
                        });
                        WriteTrace {
                            trace_id: t.trace_id,
                            parent: t.parent,
                            model: m.obs_model,
                            t_queued_ns: trace::now_ns(),
                        }
                    });
                    shared.reply(p.reply, frame, wt);
                }
            }
            WorkerEvent::Failed { worker, error, lost } => {
                log_error!("server::router",
                           "model '{}' worker {} failed: {} \
                            ({} request(s) lost)",
                           m.name, worker, error, lost.len());
                m.failures.lock().unwrap()
                    .push(format!("worker {worker}: {error}"));
                fail_ids(&shared, model_idx, &lost,
                         ErrorCode::Internal, &error);
            }
            WorkerEvent::Undeliverable { lost } => {
                log_error!("server::router",
                           "model '{}': {} request(s) undeliverable \
                            (no live workers)", m.name, lost.len());
                fail_ids(&shared, model_idx, &lost,
                         ErrorCode::ShuttingDown, "no live workers");
            }
        }
    }
    // Event stream disconnected: every worker (and the dispatcher) of
    // THIS model is gone, so none of its pending requests can ever be
    // answered — a request sitting in the queue when the last worker
    // died produced no Failed/Undeliverable event naming it. Fail this
    // model's remainder; the other models keep serving. Only when the
    // last router exits does the gateway as a whole die (loudly, via
    // drain-shutdown) — a gateway with no serviceable model must not
    // hold clients on recv forever.
    {
        let mut pending = shared.pending.lock().unwrap();
        let dead: Vec<u64> = pending.iter()
            .filter(|(_, p)| p.model == model_idx)
            .map(|(&id, _)| id)
            .collect();
        if !dead.is_empty() {
            log_error!("server::router",
                       "all workers for model '{}' exited; failing \
                        {} pending request(s)", m.name, dead.len());
        }
        for id in dead {
            if let Some(p) = pending.remove(&id) {
                shared.counters.internal.fetch_add(1, Ordering::Relaxed);
                m.counters.internal.fetch_add(1, Ordering::Relaxed);
                shared.reply(p.reply, err_frame(
                    p.version, p.client_id, ErrorCode::Internal,
                    &format!("all workers for model '{}' exited",
                             m.name)), None);
            }
        }
        if pending.is_empty() {
            shared.pending_cv.notify_all();
        }
    }
    if shared.live_routers.fetch_sub(1, Ordering::SeqCst) == 1 {
        shared.trigger_stop();
    }
}

fn fail_ids(shared: &Shared, model_idx: usize, ids: &[u64],
            code: ErrorCode, detail: &str) {
    let m = &shared.models[model_idx];
    let (counter, mcounter) = match code {
        ErrorCode::ShuttingDown => (&shared.counters.shutting_down,
                                    &m.counters.shutting_down),
        ErrorCode::Busy => (&shared.counters.busy, &m.counters.busy),
        ErrorCode::BadRequest => (&shared.counters.bad_request,
                                  &m.counters.bad_request),
        ErrorCode::Internal => (&shared.counters.internal,
                                &m.counters.internal),
    };
    let mut pending = shared.pending.lock().unwrap();
    for id in ids {
        if let Some(p) = pending.remove(id) {
            counter.fetch_add(1, Ordering::Relaxed);
            mcounter.fetch_add(1, Ordering::Relaxed);
            if let Some(t) = p.trace {
                recorder::complete(TraceMeta {
                    trace_id: t.trace_id,
                    model: m.obs_model,
                    latency_us: 0,
                    error: true,
                });
            }
            shared.reply(p.reply, err_frame(p.version, p.client_id,
                                            code, detail), None);
        }
    }
    if pending.is_empty() {
        shared.pending_cv.notify_all();
    }
}

// ----------------------------------------------------------- autoscale

/// The autoscaler's *body*: one control thread ticking every scalable
/// model's pure hysteresis controller
/// ([`Autoscaler`](crate::coordinator::Autoscaler)) against live queue
/// pressure and the p99 of the window since the previous tick, and
/// applying decisions through that model's [`PoolScaler`]. Pacing is a
/// condvar wait on the gateway stop signal, so shutdown interrupts a
/// sleeping tick instead of waiting one out.
fn autoscale_loop(cfg: AutoscaleConfig, shared: Arc<Shared>) {
    let mut ctls: Vec<Autoscaler> = shared.models.iter()
        .map(|_| Autoscaler::new(cfg.clone()))
        .collect();
    // Histogram baseline from the previous tick: p99 is computed over
    // the inter-tick window, not since process start, so the
    // controller reacts to *current* latency, not history.
    let mut bases: Vec<LatencyHistogram> = shared.models.iter()
        .map(|m| m.stats.lock().unwrap().latency().clone())
        .collect();
    loop {
        {
            let g = shared.stop_mu.lock().unwrap();
            let _ = shared.stop_cv.wait_timeout(g, cfg.tick).unwrap();
        }
        if shared.stop.load(Ordering::SeqCst) {
            return;
        }
        for (idx, m) in shared.models.iter().enumerate() {
            let Some(scaler) = &m.scaler else { continue };
            let q = m.handle.queue_stats();
            let snap = m.stats.lock().unwrap().latency().clone();
            let p99 = snap.percentile_since(&bases[idx], 99.0);
            bases[idx] = snap;
            let obs = AutoscaleObs {
                depth_frac: q.depth as f64 / q.capacity.max(1) as f64,
                cost_frac: if q.cost_capacity == u64::MAX
                    || q.cost_capacity == 0
                {
                    0.0
                } else {
                    q.cost_depth as f64 / q.cost_capacity as f64
                },
                p99_us: p99,
                current: scaler.target(),
            };
            let Some(decision) = ctls[idx].tick(&obs) else {
                continue;
            };
            let t0 = if trace::enabled() { trace::now_ns() } else { 0 };
            let from = scaler.target();
            let to = scaler.scale_to(decision.target());
            m.autoscale_events.fetch_add(1, Ordering::Relaxed);
            log_info!("server::autoscale",
                      "model '{}': pool {from} -> {to} ({decision:?}, \
                       depth {:.0}%, cost {:.0}%, window p99 {p99}us)",
                      m.name, obs.depth_frac * 100.0,
                      obs.cost_frac * 100.0);
            // Scale events are rare and operationally interesting:
            // record each as its own root trace so `skydiver trace`
            // shows them on the same timeline as the requests that
            // provoked them.
            if trace::enabled() {
                let tid = trace::gen_trace_id();
                trace::span(tid, 0, Stage::Scale, m.obs_model, t0,
                            false, from as u64, to as u64);
                recorder::complete(TraceMeta {
                    trace_id: tid,
                    model: m.obs_model,
                    latency_us: 0,
                    error: false,
                });
            }
        }
    }
}

// ------------------------------------------------------------- metrics

fn push_metric(out: &mut String, name: &str, kind: &str, v: f64) {
    use std::fmt::Write as _;
    let _ = writeln!(out, "# TYPE {name} {kind}");
    let _ = writeln!(out, "{name} {v}");
}

/// One `# TYPE` line, then one `{model="<name>"}`-labelled sample per
/// model — the single emission path for every per-model series.
fn push_labelled(out: &mut String, shared: &Shared, name: &str,
                 kind: &str, values: &[f64]) {
    use std::fmt::Write as _;
    let _ = writeln!(out, "# TYPE {name} {kind}");
    for (m, v) in shared.models.iter().zip(values) {
        let _ = writeln!(out, "{name}{{model=\"{}\"}} {v}", m.name);
    }
}

/// Prometheus-style plaintext exposition: gateway-wide counters
/// (unlabelled, as in protocol v1 days), connection-lifecycle and
/// per-shard reactor series, plus per-model series labelled
/// `{model="<name>"}` — admission counters, queue, serving report and
/// latency quantiles per mounted model.
fn render_metrics(shared: &Shared) -> String {
    use std::fmt::Write as _;
    let c = shared.counters.snapshot();
    let mut out = String::with_capacity(4096);
    push_metric(&mut out, "skydiver_models_mounted", "gauge",
                shared.models.len() as f64);
    push_metric(&mut out, "skydiver_connections_accepted_total",
                "counter", c.conns_accepted as f64);
    push_metric(&mut out, "skydiver_connections_rejected_total",
                "counter", c.conns_rejected as f64);
    // Total connections the gateway dropped to protect itself: cap
    // rejects at accept + mid-life write-backpressure sheds.
    push_metric(&mut out, "skydiver_connections_shed_total",
                "counter", (c.conns_rejected + c.conns_shed) as f64);
    push_metric(&mut out,
                "skydiver_connections_backpressure_shed_total",
                "counter", c.conns_shed as f64);
    push_metric(&mut out, "skydiver_connections_active", "gauge",
                c.conns_active as f64);
    push_metric(&mut out, "skydiver_reactor_shards", "gauge",
                shared.shards.len() as f64);
    let _ = writeln!(out,
                     "# TYPE skydiver_reactor_wakeups_total counter");
    for (i, s) in shared.shards.iter().enumerate() {
        let _ = writeln!(
            out, "skydiver_reactor_wakeups_total{{shard=\"{i}\"}} {}",
            s.wakeups.load(Ordering::Relaxed));
    }
    let _ = writeln!(out,
                     "# TYPE skydiver_reactor_connections gauge");
    for (i, s) in shared.shards.iter().enumerate() {
        let _ = writeln!(
            out, "skydiver_reactor_connections{{shard=\"{i}\"}} {}",
            s.connections.load(Ordering::Relaxed));
    }
    push_metric(&mut out, "skydiver_requests_total", "counter",
                c.requests as f64);
    push_metric(&mut out, "skydiver_served_total", "counter",
                c.served as f64);
    push_metric(&mut out, "skydiver_busy_total", "counter",
                c.busy as f64);
    push_metric(&mut out, "skydiver_bad_request_total", "counter",
                c.bad_request as f64);
    push_metric(&mut out, "skydiver_shutting_down_total", "counter",
                c.shutting_down as f64);
    push_metric(&mut out, "skydiver_internal_error_total", "counter",
                c.internal as f64);

    // One snapshot per model per scrape, so every series of one
    // exposition comes from the same instant (a scrape that locked
    // the queue once per metric could show pushed-popped != depth).
    let wall = shared.started.elapsed().as_secs_f64();
    let mcs: Vec<ModelCounterSnapshot> =
        shared.models.iter().map(|m| m.counters.snapshot()).collect();
    let queues: Vec<crate::coordinator::QueueStats> = shared.models
        .iter().map(|m| m.handle.queue_stats()).collect();
    let reports: Vec<ServingReport> = shared.models.iter()
        .map(|m| m.stats.lock().unwrap().report(wall, crate::CLOCK_HZ,
                                                m.workers))
        .collect();

    let col = |f: &dyn Fn(usize) -> f64| -> Vec<f64> {
        (0..shared.models.len()).map(f).collect()
    };
    // Per-model admission counters.
    push_labelled(&mut out, shared, "skydiver_model_requests_total",
                  "counter", &col(&|i| mcs[i].requests as f64));
    push_labelled(&mut out, shared, "skydiver_model_served_total",
                  "counter", &col(&|i| mcs[i].served as f64));
    push_labelled(&mut out, shared, "skydiver_model_busy_total",
                  "counter", &col(&|i| mcs[i].busy as f64));
    push_labelled(&mut out, shared,
                  "skydiver_model_bad_request_total", "counter",
                  &col(&|i| mcs[i].bad_request as f64));
    push_labelled(&mut out, shared,
                  "skydiver_model_internal_error_total", "counter",
                  &col(&|i| mcs[i].internal as f64));
    // Degradation: served-but-reduced-T responses (subset of served).
    push_labelled(&mut out, shared,
                  "skydiver_model_degraded_total", "counter",
                  &col(&|i| mcs[i].degraded as f64));
    // Autoscaling: live pool-size target and scale events applied.
    // Fixed-pool models report their configured worker count and a
    // frozen zero event counter.
    push_labelled(&mut out, shared, "skydiver_autoscale_workers",
                  "gauge", &col(&|i| {
                      let m = &shared.models[i];
                      m.scaler.as_ref().map(|s| s.target())
                          .unwrap_or(m.workers) as f64
                  }));
    push_labelled(&mut out, shared,
                  "skydiver_autoscale_events_total", "counter",
                  &col(&|i| shared.models[i].autoscale_events
                      .load(Ordering::Relaxed) as f64));
    // Per-model queue state.
    push_labelled(&mut out, shared, "skydiver_queue_depth", "gauge",
                  &col(&|i| queues[i].depth as f64));
    push_labelled(&mut out, shared, "skydiver_queue_capacity", "gauge",
                  &col(&|i| queues[i].capacity as f64));
    push_labelled(&mut out, shared, "skydiver_queue_max_depth", "gauge",
                  &col(&|i| queues[i].max_depth as f64));
    push_labelled(&mut out, shared, "skydiver_queue_pushed_total",
                  "counter", &col(&|i| queues[i].pushed as f64));
    push_labelled(&mut out, shared, "skydiver_queue_popped_total",
                  "counter", &col(&|i| queues[i].popped as f64));
    // Cost-denominated queue state (0 = uncapped: u64::MAX as a gauge
    // would only obscure the "no cap" case).
    push_labelled(&mut out, shared, "skydiver_queue_cost_depth",
                  "gauge", &col(&|i| queues[i].cost_depth as f64));
    push_labelled(&mut out, shared, "skydiver_queue_cost_capacity",
                  "gauge", &col(&|i| {
                      if queues[i].cost_capacity == u64::MAX {
                          0.0
                      } else {
                          queues[i].cost_capacity as f64
                      }
                  }));
    // Request-level APRC series: admission flow in predicted-cost
    // units and the predictor's live calibration quality.
    push_labelled(&mut out, shared,
                  "skydiver_predicted_cost_admitted_total", "counter",
                  &col(&|i| mcs[i].cost_admitted as f64));
    push_labelled(&mut out, shared,
                  "skydiver_predicted_cost_served_total", "counter",
                  &col(&|i| mcs[i].cost_served as f64));
    push_labelled(&mut out, shared,
                  "skydiver_predicted_cost_shed_total", "counter",
                  &col(&|i| mcs[i].cost_shed as f64));
    push_labelled(&mut out, shared,
                  "skydiver_predicted_cost_mean", "gauge",
                  &col(&|i| reports[i].mean_predicted_cost));
    push_labelled(&mut out, shared,
                  "skydiver_cost_calibration_error", "gauge",
                  &col(&|i| reports[i].cost_calibration_error));
    // Per-model serving reports (histogram-backed).
    push_labelled(&mut out, shared, "skydiver_frames_served_total",
                  "counter", &col(&|i| reports[i].frames as f64));
    push_labelled(&mut out, shared, "skydiver_served_fps", "gauge",
                  &col(&|i| reports[i].served_fps));
    // Balance ratios carry the model's dispatch mode, so FIFO-vs-cost
    // comparisons read straight off the metrics endpoint.
    let _ = writeln!(out, "# TYPE skydiver_host_balance_ratio gauge");
    for (m, rep) in shared.models.iter().zip(&reports) {
        let _ = writeln!(
            out,
            "skydiver_host_balance_ratio{{model=\"{}\",dispatch=\
             \"{}\"}} {}", m.name, m.dispatch, rep.host_balance_ratio);
    }
    let _ = writeln!(out, "# TYPE skydiver_cost_balance_ratio gauge");
    for (m, rep) in shared.models.iter().zip(&reports) {
        let _ = writeln!(
            out,
            "skydiver_cost_balance_ratio{{model=\"{}\",dispatch=\
             \"{}\"}} {}", m.name, m.dispatch, rep.cost_balance_ratio);
    }
    push_labelled(&mut out, shared, "skydiver_sim_fps", "gauge",
                  &col(&|i| reports[i].sim_fps));
    push_labelled(&mut out, shared, "skydiver_sim_energy_uj_mean",
                  "gauge", &col(&|i| reports[i].mean_energy_uj));
    let _ = writeln!(out, "# TYPE skydiver_latency_us summary");
    for (m, rep) in shared.models.iter().zip(&reports) {
        for (quant, v) in [("0.5", rep.p50_us), ("0.95", rep.p95_us),
                           ("0.99", rep.p99_us)] {
            let _ = writeln!(
                out,
                "skydiver_latency_us{{model=\"{}\",quantile=\
                 \"{quant}\"}} {v}", m.name);
        }
    }
    let _ = writeln!(out, "# TYPE skydiver_worker_frames_total counter");
    for (m, rep) in shared.models.iter().zip(&reports) {
        for (i, n) in rep.per_worker.iter().enumerate() {
            let _ = writeln!(
                out,
                "skydiver_worker_frames_total{{model=\"{}\",\
                 worker=\"{i}\"}} {n}", m.name);
        }
    }
    crate::obs::render_build_info(&mut out);
    trace::render_stage_metrics(&mut out);
    out
}
