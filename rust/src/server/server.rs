//! TCP gateway: the network front end of the serving coordinator.
//!
//! ```text
//! clients ──TCP──> accept loop ──> per-connection reader threads
//!                                      │  validate + try_submit
//!                                      v            (Full -> BUSY)
//!                          [ Service bounded queue ] <── pull ── workers
//!                                      │ WorkerEvent
//!                                      v
//!                                router thread ──> per-connection
//!                                (match by id)      writer threads
//! ```
//!
//! Design rules:
//!
//! * **Shed, never hang.** Admission is [`ServiceHandle::try_submit`];
//!   a full queue maps to a `BUSY` error response immediately. A
//!   connection beyond the cap gets one `BUSY` frame and a close.
//! * **Pipelined.** A connection may have any number of requests in
//!   flight; responses carry the request id and may arrive out of
//!   order (different workers finish at different times).
//! * **Per-request failure.** Malformed bodies get `BAD_REQUEST` on
//!   that request only; framing damage (bad magic, oversized length)
//!   poisons the stream and drops the connection — both without
//!   touching the worker pool.
//! * **Drain then stop.** Shutdown (wire `Shutdown` message or
//!   [`Gateway::stop_handle`]) stops admission, waits for in-flight
//!   requests to finish (bounded by `drain_timeout`), then shuts the
//!   service down and force-closes lingering connections.

use std::collections::HashMap;
use std::io::{self, BufReader, BufWriter, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::{FramePayload, Service, ServiceConfig,
                         ServiceHandle, ServingReport, Stats,
                         SubmitError, WorkerConfig, WorkerEvent};

use super::protocol::{net_code, read_frame, write_frame, ErrorCode,
                      RequestBody, ResponseBody, WirePayload,
                      WireRequest, WireResponse, CONN_ERR_ID,
                      KIND_REQUEST};

/// Gateway-level knobs.
#[derive(Debug, Clone)]
pub struct GatewayConfig {
    /// Bind address; port 0 picks an ephemeral port (read it back via
    /// [`Gateway::local_addr`]).
    pub addr: String,
    /// Max simultaneously served connections; one beyond the cap gets
    /// a `BUSY` error frame and an immediate close.
    pub max_conns: usize,
    /// How long shutdown waits for in-flight requests before failing
    /// them with `SHUTTING_DOWN`.
    pub drain_timeout: Duration,
}

impl Default for GatewayConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:0".into(),
            max_conns: 64,
            drain_timeout: Duration::from_secs(10),
        }
    }
}

/// Monotonic gateway counters (all atomics — readable from any
/// thread, rendered by the `metrics` request).
#[derive(Default)]
struct Counters {
    conns_accepted: AtomicU64,
    conns_active: AtomicU64,
    conns_rejected: AtomicU64,
    requests: AtomicU64,
    served: AtomicU64,
    busy: AtomicU64,
    bad_request: AtomicU64,
    shutting_down: AtomicU64,
    internal: AtomicU64,
}

/// Point-in-time copy of the gateway counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CounterSnapshot {
    pub conns_accepted: u64,
    pub conns_active: u64,
    pub conns_rejected: u64,
    /// Infer requests received (valid or not).
    pub requests: u64,
    /// Infer requests answered with a successful prediction.
    pub served: u64,
    /// Requests shed with `BUSY` (queue full).
    pub busy: u64,
    pub bad_request: u64,
    pub shutting_down: u64,
    /// Requests failed because a worker died holding them.
    pub internal: u64,
}

impl Counters {
    fn snapshot(&self) -> CounterSnapshot {
        let ld = |a: &AtomicU64| a.load(Ordering::Relaxed);
        CounterSnapshot {
            conns_accepted: ld(&self.conns_accepted),
            conns_active: ld(&self.conns_active),
            conns_rejected: ld(&self.conns_rejected),
            requests: ld(&self.requests),
            served: ld(&self.served),
            busy: ld(&self.busy),
            bad_request: ld(&self.bad_request),
            shutting_down: ld(&self.shutting_down),
            internal: ld(&self.internal),
        }
    }
}

/// Final gateway summary returned by [`Gateway::wait`].
#[derive(Debug, Clone)]
pub struct GatewayReport {
    /// The coordinator-level serving view (latency percentiles from
    /// the bounded histogram, balance, sim FPS/energy).
    pub serving: ServingReport,
    pub counters: CounterSnapshot,
}

struct PendingEntry {
    tx: mpsc::Sender<WireResponse>,
    client_id: u64,
}

/// State shared by the accept loop, router, and connection threads.
struct Shared {
    handle: ServiceHandle,
    /// internal id -> who to answer. Inserted *before* submit so a
    /// response can never race past its route.
    pending: Mutex<HashMap<u64, PendingEntry>>,
    stats: Mutex<Stats>,
    failures: Mutex<Vec<String>>,
    counters: Counters,
    next_id: AtomicU64,
    conn_seq: AtomicU64,
    /// Drain trigger: stops admission and the accept loop.
    stop: AtomicBool,
    /// One socket clone per *live* connection (removed on connection
    /// exit — bounded), for force-closing lingering connections at
    /// shutdown (readers blocked in `read` otherwise never exit).
    conns: Mutex<HashMap<u64, TcpStream>>,
    started: Instant,
    workers: usize,
}

/// Remote-controllable drain trigger (cheap clone).
#[derive(Clone)]
pub struct GatewayStop(Arc<Shared>);

impl GatewayStop {
    /// Begin drain-then-shutdown, exactly like a wire `Shutdown`
    /// message.
    pub fn trigger(&self) {
        self.0.stop.store(true, Ordering::SeqCst);
    }
}

/// A running gateway: a bound listener, its accept loop, the response
/// router, and the owned [`Service`].
pub struct Gateway {
    addr: SocketAddr,
    shared: Arc<Shared>,
    service: Service,
    accept: thread::JoinHandle<()>,
    router: thread::JoinHandle<()>,
    drain_timeout: Duration,
}

impl Gateway {
    /// Start the service, bind, and begin accepting. Artifact problems
    /// fail here (inside `Service::start`), before the port opens.
    pub fn start(gcfg: GatewayConfig, scfg: ServiceConfig,
                 wcfg: WorkerConfig) -> Result<Self> {
        let mut service = Service::start(scfg, wcfg)?;
        let events = service.take_events()?;
        let handle = service.handle();
        let workers = service.worker_count();
        let listener = TcpListener::bind(&gcfg.addr)
            .with_context(|| format!("binding {}", gcfg.addr))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;

        let shared = Arc::new(Shared {
            handle,
            pending: Mutex::new(HashMap::new()),
            stats: Mutex::new(Stats::default()),
            failures: Mutex::new(Vec::new()),
            counters: Counters::default(),
            next_id: AtomicU64::new(1),
            conn_seq: AtomicU64::new(1),
            stop: AtomicBool::new(false),
            conns: Mutex::new(HashMap::new()),
            started: Instant::now(),
            workers,
        });

        let router = {
            let shared = shared.clone();
            thread::Builder::new()
                .name("skydiver-router".into())
                .spawn(move || router_loop(events, shared))?
        };
        let accept = {
            let shared = shared.clone();
            let max_conns = gcfg.max_conns.max(1);
            thread::Builder::new()
                .name("skydiver-accept".into())
                .spawn(move || {
                    accept_loop(listener, shared, max_conns)
                })?
        };

        Ok(Self {
            addr,
            shared,
            service,
            accept,
            router,
            drain_timeout: gcfg.drain_timeout,
        })
    }

    /// The actually-bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// A handle that can trigger drain-then-shutdown from any thread.
    pub fn stop_handle(&self) -> GatewayStop {
        GatewayStop(self.shared.clone())
    }

    /// Live counter snapshot (tests / banners).
    pub fn counters(&self) -> CounterSnapshot {
        self.shared.counters.snapshot()
    }

    /// Block until shutdown is triggered (wire message or
    /// [`Self::stop_handle`]), then drain and tear down.
    pub fn wait(self) -> Result<GatewayReport> {
        while !self.shared.stop.load(Ordering::SeqCst) {
            thread::sleep(Duration::from_millis(25));
        }
        self.finish()
    }

    /// Trigger shutdown and tear down immediately (still drains).
    pub fn stop_and_wait(self) -> Result<GatewayReport> {
        self.shared.stop.store(true, Ordering::SeqCst);
        self.finish()
    }

    fn finish(self) -> Result<GatewayReport> {
        let Gateway {
            shared,
            service,
            accept,
            router,
            drain_timeout,
            ..
        } = self;
        // Accept loop polls the stop flag; joining is bounded.
        let _ = accept.join();
        // Drain: in-flight requests finish as workers catch up (new
        // admissions are already refused with SHUTTING_DOWN).
        let deadline = Instant::now() + drain_timeout;
        while Instant::now() < deadline {
            if shared.pending.lock().unwrap().is_empty() {
                break;
            }
            thread::sleep(Duration::from_millis(10));
        }
        // Whatever outlived the drain window is failed, not stranded.
        {
            let mut pending = shared.pending.lock().unwrap();
            for (_, p) in pending.drain() {
                shared.counters.shutting_down
                    .fetch_add(1, Ordering::Relaxed);
                let _ = p.tx.send(err_resp(
                    p.client_id, ErrorCode::ShuttingDown,
                    "gateway drain timeout"));
            }
        }
        // Close the queue and join workers; their event senders drop,
        // which ends the router.
        let service_result = service.shutdown();
        let _ = router.join();
        // Force-close lingering connections so blocked readers exit
        // (connection threads are detached; wait for the active count
        // to hit zero, bounded).
        for (_, s) in shared.conns.lock().unwrap().drain() {
            let _ = s.shutdown(Shutdown::Both);
        }
        let conn_deadline = Instant::now() + Duration::from_secs(5);
        while shared.counters.conns_active.load(Ordering::SeqCst) > 0
            && Instant::now() < conn_deadline
        {
            thread::sleep(Duration::from_millis(5));
        }

        let mut serving = shared.stats.lock().unwrap().report(
            shared.started.elapsed().as_secs_f64(), crate::CLOCK_HZ,
            shared.workers);
        let q = shared.handle.queue_stats();
        serving.queue_capacity = q.capacity;
        serving.queue_max_depth = q.max_depth;
        serving.worker_failures =
            shared.failures.lock().unwrap().clone();
        let counters = shared.counters.snapshot();
        service_result?;
        Ok(GatewayReport { serving, counters })
    }
}

fn err_resp(id: u64, code: ErrorCode, detail: &str) -> WireResponse {
    WireResponse {
        id,
        body: ResponseBody::Error { code, detail: detail.to_string() },
    }
}

// --------------------------------------------------------- accept loop

fn accept_loop(listener: TcpListener, shared: Arc<Shared>,
               max_conns: usize) {
    loop {
        if shared.stop.load(Ordering::SeqCst) {
            return;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                shared.counters.conns_accepted
                    .fetch_add(1, Ordering::Relaxed);
                let active = shared.counters.conns_active
                    .load(Ordering::SeqCst);
                if active >= max_conns as u64 {
                    shared.counters.conns_rejected
                        .fetch_add(1, Ordering::Relaxed);
                    shed_connection(stream);
                    continue;
                }
                shared.counters.conns_active
                    .fetch_add(1, Ordering::SeqCst);
                let conn_id =
                    shared.conn_seq.fetch_add(1, Ordering::Relaxed);
                let sh = shared.clone();
                // Detached: lifetime is bounded by the socket, which
                // `finish` force-closes; `conns_active` is the join.
                let spawned = thread::Builder::new()
                    .name("skydiver-conn".into())
                    .spawn(move || {
                        handle_conn(stream, conn_id, &sh);
                        sh.conns.lock().unwrap().remove(&conn_id);
                        sh.counters.conns_active
                            .fetch_sub(1, Ordering::SeqCst);
                    });
                if spawned.is_err() {
                    shared.counters.conns_active
                        .fetch_sub(1, Ordering::SeqCst);
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                thread::sleep(Duration::from_millis(15));
            }
            Err(_) => thread::sleep(Duration::from_millis(15)),
        }
    }
}

/// Over-cap connection: one typed `BUSY` frame, then close — the
/// client learns *why* instead of seeing a bare RST.
fn shed_connection(mut stream: TcpStream) {
    let resp = err_resp(CONN_ERR_ID, ErrorCode::Busy,
                        "connection cap reached; retry later");
    let _ = stream.write_all(&resp.encode());
    let _ = stream.flush();
    let _ = stream.shutdown(Shutdown::Both);
}

// --------------------------------------------------------- connections

fn handle_conn(stream: TcpStream, conn_id: u64, shared: &Arc<Shared>) {
    let _ = stream.set_nodelay(true);
    let reader_stream = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let ctl = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    shared.conns.lock().unwrap().insert(conn_id, ctl);
    let (tx, rx) = mpsc::channel::<WireResponse>();
    let writer = match thread::Builder::new()
        .name("skydiver-conn-writer".into())
        .spawn(move || writer_loop(stream, rx))
    {
        Ok(h) => h,
        Err(_) => return,
    };
    read_loop(reader_stream, shared, &tx);
    drop(tx);
    let _ = writer.join();
    // The registry clone keeps the fd alive until removed by our
    // caller; shut the TCP stream down explicitly so the peer sees
    // FIN now.
    if let Some(s) = shared.conns.lock().unwrap().get(&conn_id) {
        let _ = s.shutdown(Shutdown::Both);
    }
}

/// Serialize responses onto the socket. Responses from the router and
/// from the reader (errors, metrics) interleave through one channel,
/// so frames never interleave mid-frame. Batches writes: flush only
/// when the channel momentarily empties.
fn writer_loop(stream: TcpStream, rx: mpsc::Receiver<WireResponse>) {
    let mut w = BufWriter::new(stream);
    while let Ok(resp) = rx.recv() {
        if write_frame(&mut w, &resp.encode()).is_err() {
            return;
        }
        while let Ok(next) = rx.try_recv() {
            if write_frame(&mut w, &next.encode()).is_err() {
                return;
            }
        }
        if w.flush().is_err() {
            return;
        }
    }
}

fn read_loop(stream: TcpStream, shared: &Arc<Shared>,
             tx: &mpsc::Sender<WireResponse>) {
    let mut r = BufReader::new(stream);
    loop {
        let body = match read_frame(&mut r, KIND_REQUEST) {
            Ok(Some(body)) => body,
            // Clean close between frames.
            Ok(None) => return,
            Err(e) => {
                // Framing damage: the stream is desynced. Answer once
                // (best effort) so the peer learns why, then drop.
                shared.counters.bad_request
                    .fetch_add(1, Ordering::Relaxed);
                let _ = tx.send(err_resp(
                    CONN_ERR_ID, ErrorCode::BadRequest, &e.to_string()));
                return;
            }
        };
        let req = match WireRequest::decode_body(&body) {
            Ok(req) => req,
            Err(e) => {
                // The frame boundary held: reject this request, keep
                // the connection. The request id may not have parsed,
                // so answer on the reserved connection-error id.
                shared.counters.bad_request
                    .fetch_add(1, Ordering::Relaxed);
                let _ = tx.send(err_resp(
                    CONN_ERR_ID, ErrorCode::BadRequest, &e.to_string()));
                continue;
            }
        };
        match req.body {
            RequestBody::Infer { net, payload } => {
                handle_infer(shared, tx, req.id, net, payload);
            }
            RequestBody::Metrics => {
                let text = render_metrics(shared);
                let _ = tx.send(WireResponse {
                    id: req.id,
                    body: ResponseBody::Metrics { text },
                });
            }
            RequestBody::Info => {
                let s = shared.handle.spec();
                let _ = tx.send(WireResponse {
                    id: req.id,
                    body: ResponseBody::Info {
                        net: net_code(s.kind),
                        c: s.c as u32,
                        h: s.h as u32,
                        w: s.w as u32,
                        timesteps: s.timesteps as u32,
                    },
                });
            }
            RequestBody::Shutdown => {
                let _ = tx.send(WireResponse {
                    id: req.id,
                    body: ResponseBody::ShutdownAck,
                });
                shared.stop.store(true, Ordering::SeqCst);
            }
        }
    }
}

fn handle_infer(shared: &Arc<Shared>, tx: &mpsc::Sender<WireResponse>,
                client_id: u64, net: u8, payload: WirePayload) {
    shared.counters.requests.fetch_add(1, Ordering::Relaxed);
    if shared.stop.load(Ordering::SeqCst) {
        shared.counters.shutting_down.fetch_add(1, Ordering::Relaxed);
        let _ = tx.send(err_resp(client_id, ErrorCode::ShuttingDown,
                                 "gateway is draining"));
        return;
    }
    let spec = shared.handle.spec();
    if net != net_code(spec.kind) {
        shared.counters.bad_request.fetch_add(1, Ordering::Relaxed);
        let _ = tx.send(err_resp(
            client_id, ErrorCode::BadRequest,
            &format!("server runs net {:?}, request asked for code {net}",
                     spec.kind)));
        return;
    }
    let payload = match payload {
        WirePayload::Pixels(px) => FramePayload::Pixels(px),
        WirePayload::Spikes { timesteps, words } => {
            FramePayload::Spikes { timesteps: timesteps as usize, words }
        }
    };
    // Validate against the frame contract *here*: a malformed request
    // costs one response, never a worker.
    if let Err(detail) = spec.validate(&payload) {
        shared.counters.bad_request.fetch_add(1, Ordering::Relaxed);
        let _ = tx.send(err_resp(client_id, ErrorCode::BadRequest,
                                 &detail));
        return;
    }
    let internal = shared.next_id.fetch_add(1, Ordering::Relaxed);
    shared.pending.lock().unwrap().insert(internal, PendingEntry {
        tx: tx.clone(),
        client_id,
    });
    match shared.handle.try_submit(internal, payload) {
        Ok(()) => {}
        Err(e) => {
            shared.pending.lock().unwrap().remove(&internal);
            let code = match e {
                SubmitError::Full { .. } => {
                    shared.counters.busy.fetch_add(1, Ordering::Relaxed);
                    ErrorCode::Busy
                }
                SubmitError::Closed | SubmitError::NoWorkers => {
                    shared.counters.shutting_down
                        .fetch_add(1, Ordering::Relaxed);
                    ErrorCode::ShuttingDown
                }
            };
            let _ = tx.send(err_resp(client_id, code, &e.to_string()));
        }
    }
}

// -------------------------------------------------------------- router

/// Owns the worker event stream: matches responses back to their
/// connection by internal id, folds serving stats, and fails exactly
/// the requests a dying worker had in hand.
fn router_loop(events: mpsc::Receiver<WorkerEvent>,
               shared: Arc<Shared>) {
    while let Ok(ev) = events.recv() {
        match ev {
            WorkerEvent::Served(r) => {
                shared.stats.lock().unwrap().record(&r);
                shared.counters.served.fetch_add(1, Ordering::Relaxed);
                let entry = shared.pending.lock().unwrap().remove(&r.id);
                if let Some(p) = entry {
                    let prediction = r.output_counts.iter().enumerate()
                        .max_by_key(|&(_, c)| *c)
                        .map(|(i, _)| i as u32)
                        .unwrap_or(0);
                    let _ = p.tx.send(WireResponse {
                        id: p.client_id,
                        body: ResponseBody::Infer {
                            prediction,
                            output_counts: r.output_counts,
                            latency_us: r.latency_us,
                            worker: r.worker as u32,
                        },
                    });
                }
            }
            WorkerEvent::Failed { worker, error, lost } => {
                shared.failures.lock().unwrap()
                    .push(format!("worker {worker}: {error}"));
                fail_ids(&shared, &lost, ErrorCode::Internal, &error);
            }
            WorkerEvent::Undeliverable { lost } => {
                fail_ids(&shared, &lost, ErrorCode::ShuttingDown,
                         "no live workers");
            }
        }
    }
    // Event stream disconnected: every worker (and the dispatcher) is
    // gone, so nothing still in `pending` can ever be answered — a
    // request sitting in the queue when the last worker died produced
    // no Failed/Undeliverable event naming it. Fail the remainder and
    // trigger drain-shutdown: a gateway with no workers must die
    // loudly, not hold clients on recv forever.
    {
        let mut pending = shared.pending.lock().unwrap();
        for (_, p) in pending.drain() {
            shared.counters.internal.fetch_add(1, Ordering::Relaxed);
            let _ = p.tx.send(err_resp(
                p.client_id, ErrorCode::Internal,
                "all workers exited"));
        }
    }
    shared.stop.store(true, Ordering::SeqCst);
}

fn fail_ids(shared: &Shared, ids: &[u64], code: ErrorCode,
            detail: &str) {
    let counter = match code {
        ErrorCode::ShuttingDown => &shared.counters.shutting_down,
        ErrorCode::Busy => &shared.counters.busy,
        ErrorCode::BadRequest => &shared.counters.bad_request,
        ErrorCode::Internal => &shared.counters.internal,
    };
    let mut pending = shared.pending.lock().unwrap();
    for id in ids {
        if let Some(p) = pending.remove(id) {
            counter.fetch_add(1, Ordering::Relaxed);
            let _ = p.tx.send(err_resp(p.client_id, code, detail));
        }
    }
}

// ------------------------------------------------------------- metrics

fn push_metric(out: &mut String, name: &str, kind: &str, v: f64) {
    use std::fmt::Write as _;
    let _ = writeln!(out, "# TYPE {name} {kind}");
    let _ = writeln!(out, "{name} {v}");
}

/// Prometheus-style plaintext exposition of the gateway counters, the
/// queue, and the serving report (the wire `metrics` request).
fn render_metrics(shared: &Shared) -> String {
    use std::fmt::Write as _;
    let c = shared.counters.snapshot();
    let q = shared.handle.queue_stats();
    let rep = shared.stats.lock().unwrap().report(
        shared.started.elapsed().as_secs_f64(), crate::CLOCK_HZ,
        shared.workers);
    let mut out = String::with_capacity(2048);
    push_metric(&mut out, "skydiver_connections_accepted_total",
                "counter", c.conns_accepted as f64);
    push_metric(&mut out, "skydiver_connections_rejected_total",
                "counter", c.conns_rejected as f64);
    push_metric(&mut out, "skydiver_connections_active", "gauge",
                c.conns_active as f64);
    push_metric(&mut out, "skydiver_requests_total", "counter",
                c.requests as f64);
    push_metric(&mut out, "skydiver_served_total", "counter",
                c.served as f64);
    push_metric(&mut out, "skydiver_busy_total", "counter",
                c.busy as f64);
    push_metric(&mut out, "skydiver_bad_request_total", "counter",
                c.bad_request as f64);
    push_metric(&mut out, "skydiver_shutting_down_total", "counter",
                c.shutting_down as f64);
    push_metric(&mut out, "skydiver_internal_error_total", "counter",
                c.internal as f64);
    push_metric(&mut out, "skydiver_queue_depth", "gauge",
                q.depth as f64);
    push_metric(&mut out, "skydiver_queue_capacity", "gauge",
                q.capacity as f64);
    push_metric(&mut out, "skydiver_queue_max_depth", "gauge",
                q.max_depth as f64);
    push_metric(&mut out, "skydiver_queue_pushed_total", "counter",
                q.pushed as f64);
    push_metric(&mut out, "skydiver_queue_popped_total", "counter",
                q.popped as f64);
    push_metric(&mut out, "skydiver_frames_served_total", "counter",
                rep.frames as f64);
    push_metric(&mut out, "skydiver_served_fps", "gauge",
                rep.served_fps);
    push_metric(&mut out, "skydiver_host_balance_ratio", "gauge",
                rep.host_balance_ratio);
    push_metric(&mut out, "skydiver_sim_fps", "gauge", rep.sim_fps);
    push_metric(&mut out, "skydiver_sim_energy_uj_mean", "gauge",
                rep.mean_energy_uj);
    let _ = writeln!(out, "# TYPE skydiver_latency_us summary");
    for (quant, v) in [("0.5", rep.p50_us), ("0.95", rep.p95_us),
                       ("0.99", rep.p99_us)] {
        let _ = writeln!(
            out, "skydiver_latency_us{{quantile=\"{quant}\"}} {v}");
    }
    let _ = writeln!(out, "# TYPE skydiver_worker_frames_total counter");
    for (i, n) in rep.per_worker.iter().enumerate() {
        let _ = writeln!(
            out, "skydiver_worker_frames_total{{worker=\"{i}\"}} {n}");
    }
    out
}
