//! TCP gateway: the network front end of the serving coordinator —
//! registry-routed, multi-model.
//!
//! ```text
//! clients ──TCP──> accept loop ──> per-connection reader threads
//!                                      │  resolve model, validate,
//!                                      v  try_submit (Full -> BUSY)
//!              [ model 0: Service queue ] <── pull ── workers ┐
//!              [ model 1: Service queue ] <── pull ── workers ┤
//!                                      │ WorkerEvent           │
//!                                      v                       │
//!                        per-model router threads <────────────┘
//!                        (match by id) ──> per-connection
//!                                          writer threads
//! ```
//!
//! Design rules:
//!
//! * **Registry-routed.** Every `Infer`/`Info` resolves its model
//!   selector against the [`ModelRegistry`]: the empty selector (and
//!   every protocol-v1 frame, which cannot carry one) routes to the
//!   default model (registry entry 0); an unknown name is a
//!   `BAD_REQUEST` on that request only.
//! * **Per-model isolation.** Each model owns its queue, worker pool,
//!   stats and admission counters — an overloaded or dead model sheds
//!   or fails *its* traffic while the others keep serving.
//! * **Shed, never hang.** Admission is [`ServiceHandle::try_submit`];
//!   a full queue maps to a `BUSY` error response immediately. A
//!   connection beyond the cap gets one `BUSY` frame and a close.
//! * **Pipelined.** A connection may have any number of requests in
//!   flight; responses carry the request id and may arrive out of
//!   order (different workers finish at different times). Each
//!   response is framed at the protocol version its request arrived
//!   with, so v1 and v2 clients coexist on one gateway.
//! * **Per-request failure.** Malformed bodies get `BAD_REQUEST` on
//!   that request only; framing damage (bad magic, oversized length)
//!   poisons the stream and drops the connection — both without
//!   touching any worker pool. An `Infer` using the reserved
//!   [`CONN_ERR_ID`] is refused with `BAD_REQUEST` — accepting it
//!   would make its response indistinguishable from a
//!   connection-level failure.
//! * **Drain then stop.** Shutdown (wire `Shutdown` message or
//!   [`Gateway::stop_handle`]) stops admission, waits for in-flight
//!   requests to finish (bounded by `drain_timeout`), then shuts every
//!   model down and force-closes lingering connections.

use std::collections::HashMap;
use std::io::{self, BufReader, BufWriter, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::{FramePayload, ModelRegistry, ServiceConfig,
                         ServiceHandle, ServingReport, Stats,
                         SubmitError, WorkerConfig, WorkerEvent};

use super::protocol::{net_code, read_frame, write_frame, ErrorCode,
                      RequestBody, ResponseBody, WirePayload,
                      WireRequest, WireResponse, CONN_ERR_ID,
                      KIND_REQUEST, NET_ANY, V1};

/// Gateway-level knobs.
#[derive(Debug, Clone)]
pub struct GatewayConfig {
    /// Bind address; port 0 picks an ephemeral port (read it back via
    /// [`Gateway::local_addr`]).
    pub addr: String,
    /// Max simultaneously served connections; one beyond the cap gets
    /// a `BUSY` error frame and an immediate close.
    pub max_conns: usize,
    /// How long shutdown waits for in-flight requests before failing
    /// them with `SHUTTING_DOWN`.
    pub drain_timeout: Duration,
}

impl Default for GatewayConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:0".into(),
            max_conns: 64,
            drain_timeout: Duration::from_secs(10),
        }
    }
}

/// Monotonic gateway counters (all atomics — readable from any
/// thread, rendered by the `metrics` request).
#[derive(Default)]
struct Counters {
    conns_accepted: AtomicU64,
    conns_active: AtomicU64,
    conns_rejected: AtomicU64,
    requests: AtomicU64,
    served: AtomicU64,
    busy: AtomicU64,
    bad_request: AtomicU64,
    shutting_down: AtomicU64,
    internal: AtomicU64,
}

/// Point-in-time copy of the gateway-wide counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CounterSnapshot {
    pub conns_accepted: u64,
    pub conns_active: u64,
    pub conns_rejected: u64,
    /// Infer requests admitted to routing (sum over models; excludes
    /// requests refused before a model was resolved, e.g. a reserved
    /// id or an unknown model — those only count as `bad_request`).
    pub requests: u64,
    /// Infer requests answered with a successful prediction.
    pub served: u64,
    /// Requests shed with `BUSY` (queue full).
    pub busy: u64,
    pub bad_request: u64,
    pub shutting_down: u64,
    /// Requests failed because a worker died holding them.
    pub internal: u64,
}

impl Counters {
    fn snapshot(&self) -> CounterSnapshot {
        let ld = |a: &AtomicU64| a.load(Ordering::Relaxed);
        CounterSnapshot {
            conns_accepted: ld(&self.conns_accepted),
            conns_active: ld(&self.conns_active),
            conns_rejected: ld(&self.conns_rejected),
            requests: ld(&self.requests),
            served: ld(&self.served),
            busy: ld(&self.busy),
            bad_request: ld(&self.bad_request),
            shutting_down: ld(&self.shutting_down),
            internal: ld(&self.internal),
        }
    }
}

/// Per-model admission/outcome counters (atomics). The `cost_*`
/// counters denominate the same admission flow in predicted-cost
/// units (see `coordinator::cost`), so load and shedding are visible
/// as *work*, not just request count.
#[derive(Default)]
struct ModelCounters {
    requests: AtomicU64,
    served: AtomicU64,
    busy: AtomicU64,
    bad_request: AtomicU64,
    shutting_down: AtomicU64,
    internal: AtomicU64,
    cost_admitted: AtomicU64,
    cost_served: AtomicU64,
    cost_shed: AtomicU64,
}

/// Point-in-time copy of one model's counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ModelCounterSnapshot {
    /// Infer requests routed to this model (valid or not).
    pub requests: u64,
    pub served: u64,
    pub busy: u64,
    pub bad_request: u64,
    pub shutting_down: u64,
    pub internal: u64,
    /// Predicted cost accepted into this model's queue (cost units).
    pub cost_admitted: u64,
    /// Predicted cost of successfully served responses.
    pub cost_served: u64,
    /// Predicted cost shed with `BUSY` (queue full).
    pub cost_shed: u64,
}

impl ModelCounters {
    fn snapshot(&self) -> ModelCounterSnapshot {
        let ld = |a: &AtomicU64| a.load(Ordering::Relaxed);
        ModelCounterSnapshot {
            requests: ld(&self.requests),
            served: ld(&self.served),
            busy: ld(&self.busy),
            bad_request: ld(&self.bad_request),
            shutting_down: ld(&self.shutting_down),
            internal: ld(&self.internal),
            cost_admitted: ld(&self.cost_admitted),
            cost_served: ld(&self.cost_served),
            cost_shed: ld(&self.cost_shed),
        }
    }
}

/// One mounted model as the gateway threads see it.
struct ModelRuntime {
    name: String,
    handle: ServiceHandle,
    stats: Mutex<Stats>,
    failures: Mutex<Vec<String>>,
    counters: ModelCounters,
    workers: usize,
    /// Dispatch-mode label of this model's balance metrics.
    dispatch: &'static str,
}

/// Final per-model summary inside a [`GatewayReport`].
#[derive(Debug, Clone)]
pub struct ModelReport {
    pub name: String,
    /// The coordinator-level serving view (latency percentiles from
    /// the bounded histogram, balance, sim FPS/energy).
    pub serving: ServingReport,
    pub counters: ModelCounterSnapshot,
}

/// Final gateway summary returned by [`Gateway::wait`]: gateway-wide
/// counters plus one [`ModelReport`] per mounted model, in registry
/// order (index 0 = the default model).
#[derive(Debug, Clone)]
pub struct GatewayReport {
    pub counters: CounterSnapshot,
    pub models: Vec<ModelReport>,
}

impl GatewayReport {
    /// The default model's report (registry entry 0) — the view v1
    /// single-model callers mean by "the" serving report.
    pub fn default_model(&self) -> &ModelReport {
        &self.models[0]
    }

    pub fn model(&self, name: &str) -> Option<&ModelReport> {
        self.models.iter().find(|m| m.name == name)
    }
}

struct PendingEntry {
    /// Pre-encoded frames go straight to the connection's writer.
    tx: mpsc::Sender<Vec<u8>>,
    client_id: u64,
    /// Protocol version the request arrived with — its response is
    /// framed the same way.
    version: u8,
    /// Registry slot the request was routed to.
    model: usize,
}

/// State shared by the accept loop, routers, and connection threads.
struct Shared {
    models: Vec<ModelRuntime>,
    /// internal id -> who to answer. Inserted *before* submit so a
    /// response can never race past its route.
    pending: Mutex<HashMap<u64, PendingEntry>>,
    counters: Counters,
    next_id: AtomicU64,
    conn_seq: AtomicU64,
    /// Routers still draining a live worker event stream; the last one
    /// to exit declares the gateway dead (no model can serve).
    live_routers: AtomicUsize,
    /// Drain trigger: stops admission and the accept loop.
    stop: AtomicBool,
    /// One socket clone per *live* connection (removed on connection
    /// exit — bounded), for force-closing lingering connections at
    /// shutdown (readers blocked in `read` otherwise never exit).
    conns: Mutex<HashMap<u64, TcpStream>>,
    started: Instant,
}

impl Shared {
    /// Resolve a wire selector: empty = default model (slot 0).
    fn resolve(&self, selector: &str) -> Option<usize> {
        if selector.is_empty() {
            return Some(0);
        }
        self.models.iter().position(|m| m.name == selector)
    }
}

/// Remote-controllable drain trigger (cheap clone).
#[derive(Clone)]
pub struct GatewayStop(Arc<Shared>);

impl GatewayStop {
    /// Begin drain-then-shutdown, exactly like a wire `Shutdown`
    /// message.
    pub fn trigger(&self) {
        self.0.stop.store(true, Ordering::SeqCst);
    }
}

/// A running gateway: a bound listener, its accept loop, one response
/// router per model, and the owned [`ModelRegistry`].
pub struct Gateway {
    addr: SocketAddr,
    shared: Arc<Shared>,
    registry: ModelRegistry,
    accept: thread::JoinHandle<()>,
    routers: Vec<thread::JoinHandle<()>>,
    drain_timeout: Duration,
}

impl Gateway {
    /// Start from a registry of already-running models, bind, and
    /// begin accepting.
    pub fn start(gcfg: GatewayConfig, mut registry: ModelRegistry)
                 -> Result<Self> {
        let mut runtimes = Vec::with_capacity(registry.len());
        let mut event_streams = Vec::with_capacity(registry.len());
        for idx in 0..registry.len() {
            let entry = registry.entry_mut(idx);
            let events = entry.service_mut().take_events()?;
            let service = entry.service();
            runtimes.push(ModelRuntime {
                name: entry.name().to_string(),
                handle: service.handle(),
                stats: Mutex::new(Stats::default()),
                failures: Mutex::new(Vec::new()),
                counters: ModelCounters::default(),
                workers: service.worker_count(),
                dispatch: service.dispatch_mode().as_str(),
            });
            event_streams.push(events);
        }
        let listener = TcpListener::bind(&gcfg.addr)
            .with_context(|| format!("binding {}", gcfg.addr))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;

        let shared = Arc::new(Shared {
            models: runtimes,
            pending: Mutex::new(HashMap::new()),
            counters: Counters::default(),
            next_id: AtomicU64::new(1),
            conn_seq: AtomicU64::new(1),
            live_routers: AtomicUsize::new(event_streams.len()),
            stop: AtomicBool::new(false),
            conns: Mutex::new(HashMap::new()),
            started: Instant::now(),
        });

        let mut routers = Vec::with_capacity(event_streams.len());
        for (idx, events) in event_streams.into_iter().enumerate() {
            let shared = shared.clone();
            routers.push(thread::Builder::new()
                .name(format!("skydiver-router-{idx}"))
                .spawn(move || router_loop(idx, events, shared))?);
        }
        let accept = {
            let shared = shared.clone();
            let max_conns = gcfg.max_conns.max(1);
            thread::Builder::new()
                .name("skydiver-accept".into())
                .spawn(move || {
                    accept_loop(listener, shared, max_conns)
                })?
        };

        Ok(Self {
            addr,
            shared,
            registry,
            accept,
            routers,
            drain_timeout: gcfg.drain_timeout,
        })
    }

    /// Single-model convenience: mount one service under its net's
    /// canonical name ([`NetKind::as_str`](crate::snn::NetKind::as_str))
    /// — the v1 topology as a one-entry registry.
    pub fn start_single(gcfg: GatewayConfig, scfg: ServiceConfig,
                        wcfg: WorkerConfig) -> Result<Self> {
        let name = wcfg.kind.as_str();
        let registry = ModelRegistry::single(name, scfg, wcfg)?;
        Self::start(gcfg, registry)
    }

    /// The actually-bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Mounted model names, registry order (index 0 = default).
    pub fn model_names(&self) -> Vec<&str> {
        self.registry.names()
    }

    /// A handle that can trigger drain-then-shutdown from any thread.
    pub fn stop_handle(&self) -> GatewayStop {
        GatewayStop(self.shared.clone())
    }

    /// Live gateway-wide counter snapshot (tests / banners).
    pub fn counters(&self) -> CounterSnapshot {
        self.shared.counters.snapshot()
    }

    /// Live counter snapshot for one model (by registry slot).
    pub fn model_counters(&self, idx: usize) -> ModelCounterSnapshot {
        self.shared.models[idx].counters.snapshot()
    }

    /// Block until shutdown is triggered (wire message or
    /// [`Self::stop_handle`]), then drain and tear down.
    pub fn wait(self) -> Result<GatewayReport> {
        while !self.shared.stop.load(Ordering::SeqCst) {
            thread::sleep(Duration::from_millis(25));
        }
        self.finish()
    }

    /// Trigger shutdown and tear down immediately (still drains).
    pub fn stop_and_wait(self) -> Result<GatewayReport> {
        self.shared.stop.store(true, Ordering::SeqCst);
        self.finish()
    }

    fn finish(self) -> Result<GatewayReport> {
        let Gateway {
            shared,
            registry,
            accept,
            routers,
            drain_timeout,
            ..
        } = self;
        // Accept loop polls the stop flag; joining is bounded.
        let _ = accept.join();
        // Drain: in-flight requests finish as workers catch up (new
        // admissions are already refused with SHUTTING_DOWN).
        let deadline = Instant::now() + drain_timeout;
        while Instant::now() < deadline {
            if shared.pending.lock().unwrap().is_empty() {
                break;
            }
            thread::sleep(Duration::from_millis(10));
        }
        // Whatever outlived the drain window is failed, not stranded.
        {
            let mut pending = shared.pending.lock().unwrap();
            for (_, p) in pending.drain() {
                shared.counters.shutting_down
                    .fetch_add(1, Ordering::Relaxed);
                shared.models[p.model].counters.shutting_down
                    .fetch_add(1, Ordering::Relaxed);
                let _ = p.tx.send(err_frame(
                    p.version, p.client_id, ErrorCode::ShuttingDown,
                    "gateway drain timeout"));
            }
        }
        // Close every queue and join workers; their event senders
        // drop, which ends the routers.
        let registry_result = registry.shutdown();
        for r in routers {
            let _ = r.join();
        }
        // Force-close lingering connections so blocked readers exit
        // (connection threads are detached; wait for the active count
        // to hit zero, bounded).
        for (_, s) in shared.conns.lock().unwrap().drain() {
            let _ = s.shutdown(Shutdown::Both);
        }
        let conn_deadline = Instant::now() + Duration::from_secs(5);
        while shared.counters.conns_active.load(Ordering::SeqCst) > 0
            && Instant::now() < conn_deadline
        {
            thread::sleep(Duration::from_millis(5));
        }

        let wall = shared.started.elapsed().as_secs_f64();
        let models = shared.models.iter().map(|m| {
            let mut serving = m.stats.lock().unwrap().report(
                wall, crate::CLOCK_HZ, m.workers);
            let q = m.handle.queue_stats();
            serving.queue_capacity = q.capacity;
            serving.queue_max_depth = q.max_depth;
            serving.worker_failures =
                m.failures.lock().unwrap().clone();
            ModelReport {
                name: m.name.clone(),
                serving,
                counters: m.counters.snapshot(),
            }
        }).collect();
        let counters = shared.counters.snapshot();
        registry_result?;
        Ok(GatewayReport { counters, models })
    }
}

fn err_resp(id: u64, code: ErrorCode, detail: &str) -> WireResponse {
    WireResponse {
        id,
        body: ResponseBody::Error { code, detail: detail.to_string() },
    }
}

/// Encode an error response at the peer's protocol version.
fn err_frame(version: u8, id: u64, code: ErrorCode, detail: &str)
             -> Vec<u8> {
    err_resp(id, code, detail).encode(version)
}

// --------------------------------------------------------- accept loop

fn accept_loop(listener: TcpListener, shared: Arc<Shared>,
               max_conns: usize) {
    loop {
        if shared.stop.load(Ordering::SeqCst) {
            return;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                shared.counters.conns_accepted
                    .fetch_add(1, Ordering::Relaxed);
                let active = shared.counters.conns_active
                    .load(Ordering::SeqCst);
                if active >= max_conns as u64 {
                    shared.counters.conns_rejected
                        .fetch_add(1, Ordering::Relaxed);
                    shed_connection(stream);
                    continue;
                }
                shared.counters.conns_active
                    .fetch_add(1, Ordering::SeqCst);
                let conn_id =
                    shared.conn_seq.fetch_add(1, Ordering::Relaxed);
                let sh = shared.clone();
                // Detached: lifetime is bounded by the socket, which
                // `finish` force-closes; `conns_active` is the join.
                let spawned = thread::Builder::new()
                    .name("skydiver-conn".into())
                    .spawn(move || {
                        handle_conn(stream, conn_id, &sh);
                        sh.conns.lock().unwrap().remove(&conn_id);
                        sh.counters.conns_active
                            .fetch_sub(1, Ordering::SeqCst);
                    });
                if spawned.is_err() {
                    shared.counters.conns_active
                        .fetch_sub(1, Ordering::SeqCst);
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                thread::sleep(Duration::from_millis(15));
            }
            Err(_) => thread::sleep(Duration::from_millis(15)),
        }
    }
}

/// Over-cap connection: one typed `BUSY` frame, then close — the
/// client learns *why* instead of seeing a bare RST. Framed at v1 —
/// nothing from the peer has been read yet, and every client version
/// decodes v1 response frames.
fn shed_connection(mut stream: TcpStream) {
    let frame = err_frame(V1, CONN_ERR_ID, ErrorCode::Busy,
                          "connection cap reached; retry later");
    let _ = stream.write_all(&frame);
    let _ = stream.flush();
    let _ = stream.shutdown(Shutdown::Both);
}

// --------------------------------------------------------- connections

fn handle_conn(stream: TcpStream, conn_id: u64, shared: &Arc<Shared>) {
    let _ = stream.set_nodelay(true);
    let reader_stream = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let ctl = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    shared.conns.lock().unwrap().insert(conn_id, ctl);
    let (tx, rx) = mpsc::channel::<Vec<u8>>();
    let writer = match thread::Builder::new()
        .name("skydiver-conn-writer".into())
        .spawn(move || writer_loop(stream, rx))
    {
        Ok(h) => h,
        Err(_) => return,
    };
    read_loop(reader_stream, shared, &tx);
    drop(tx);
    let _ = writer.join();
    // The registry clone keeps the fd alive until removed by our
    // caller; shut the TCP stream down explicitly so the peer sees
    // FIN now.
    if let Some(s) = shared.conns.lock().unwrap().get(&conn_id) {
        let _ = s.shutdown(Shutdown::Both);
    }
}

/// Serialize pre-encoded response frames onto the socket. Frames from
/// the routers and from the reader (errors, metrics) interleave
/// through one channel, so they never interleave mid-frame. Batches
/// writes: flush only when the channel momentarily empties.
fn writer_loop(stream: TcpStream, rx: mpsc::Receiver<Vec<u8>>) {
    let mut w = BufWriter::new(stream);
    while let Ok(frame) = rx.recv() {
        if write_frame(&mut w, &frame).is_err() {
            return;
        }
        while let Ok(next) = rx.try_recv() {
            if write_frame(&mut w, &next).is_err() {
                return;
            }
        }
        if w.flush().is_err() {
            return;
        }
    }
}

fn read_loop(stream: TcpStream, shared: &Arc<Shared>,
             tx: &mpsc::Sender<Vec<u8>>) {
    let mut r = BufReader::new(stream);
    // Version the last well-framed request arrived with — the best
    // guess for framing connection-level errors (defaults to v1,
    // which every client version decodes).
    let mut peer_ver = V1;
    loop {
        let (ver, body) = match read_frame(&mut r, KIND_REQUEST) {
            Ok(Some(x)) => x,
            // Clean close between frames.
            Ok(None) => return,
            Err(e) => {
                // Framing damage: the stream is desynced. Answer once
                // (best effort) so the peer learns why, then drop.
                shared.counters.bad_request
                    .fetch_add(1, Ordering::Relaxed);
                let _ = tx.send(err_frame(
                    peer_ver, CONN_ERR_ID, ErrorCode::BadRequest,
                    &e.to_string()));
                return;
            }
        };
        peer_ver = ver;
        let req = match WireRequest::decode_body(ver, &body) {
            Ok(req) => req,
            Err(e) => {
                // The frame boundary held: reject this request, keep
                // the connection. The request id may not have parsed,
                // so answer on the reserved connection-error id.
                shared.counters.bad_request
                    .fetch_add(1, Ordering::Relaxed);
                let _ = tx.send(err_frame(
                    ver, CONN_ERR_ID, ErrorCode::BadRequest,
                    &e.to_string()));
                continue;
            }
        };
        // The reserved id cannot name a request: its response would be
        // indistinguishable from a connection-level failure.
        if req.id == CONN_ERR_ID {
            shared.counters.bad_request
                .fetch_add(1, Ordering::Relaxed);
            let _ = tx.send(err_frame(
                ver, CONN_ERR_ID, ErrorCode::BadRequest,
                &format!("request id {CONN_ERR_ID} is reserved for \
                          connection-level errors")));
            continue;
        }
        match req.body {
            RequestBody::Infer { net, model, payload } => {
                handle_infer(shared, tx, ver, req.id, net, &model,
                             payload);
            }
            RequestBody::Metrics => {
                let text = render_metrics(shared);
                let _ = tx.send(WireResponse {
                    id: req.id,
                    body: ResponseBody::Metrics { text },
                }.encode(ver));
            }
            RequestBody::Info { model } => {
                let resp = match shared.resolve(&model) {
                    None => err_resp(req.id, ErrorCode::BadRequest,
                                     &unknown_model(shared, &model)),
                    Some(idx) => {
                        let m = &shared.models[idx];
                        let s = m.handle.spec();
                        WireResponse {
                            id: req.id,
                            body: ResponseBody::Info {
                                net: net_code(s.kind),
                                c: s.c as u32,
                                h: s.h as u32,
                                w: s.w as u32,
                                timesteps: s.timesteps as u32,
                                model: m.name.clone(),
                                nmodels: shared.models.len() as u8,
                            },
                        }
                    }
                };
                let _ = tx.send(resp.encode(ver));
            }
            RequestBody::Shutdown => {
                let _ = tx.send(WireResponse {
                    id: req.id,
                    body: ResponseBody::ShutdownAck,
                }.encode(ver));
                shared.stop.store(true, Ordering::SeqCst);
            }
        }
    }
}

fn unknown_model(shared: &Shared, selector: &str) -> String {
    let names: Vec<&str> =
        shared.models.iter().map(|m| m.name.as_str()).collect();
    format!("unknown model '{selector}'; mounted: [{}] (empty selector \
             = default '{}')", names.join(", "), names[0])
}

#[allow(clippy::too_many_arguments)]
fn handle_infer(shared: &Arc<Shared>, tx: &mpsc::Sender<Vec<u8>>,
                version: u8, client_id: u64, net: u8, model: &str,
                payload: WirePayload) {
    let idx = match shared.resolve(model) {
        Some(idx) => idx,
        None => {
            shared.counters.bad_request.fetch_add(1, Ordering::Relaxed);
            let _ = tx.send(err_frame(
                version, client_id, ErrorCode::BadRequest,
                &unknown_model(shared, model)));
            return;
        }
    };
    let m = &shared.models[idx];
    shared.counters.requests.fetch_add(1, Ordering::Relaxed);
    m.counters.requests.fetch_add(1, Ordering::Relaxed);
    if shared.stop.load(Ordering::SeqCst) {
        shared.counters.shutting_down.fetch_add(1, Ordering::Relaxed);
        m.counters.shutting_down.fetch_add(1, Ordering::Relaxed);
        let _ = tx.send(err_frame(version, client_id,
                                  ErrorCode::ShuttingDown,
                                  "gateway is draining"));
        return;
    }
    let spec = m.handle.spec();
    // v1 clients address by net code; check it against the routed
    // model so a misdirected request fails loudly instead of running
    // through the wrong network. NET_ANY (the v2 idiom) skips this.
    if net != NET_ANY && net != net_code(spec.kind) {
        shared.counters.bad_request.fetch_add(1, Ordering::Relaxed);
        m.counters.bad_request.fetch_add(1, Ordering::Relaxed);
        let _ = tx.send(err_frame(
            version, client_id, ErrorCode::BadRequest,
            &format!("model '{}' runs net {:?}, request asked for \
                      code {net}", m.name, spec.kind)));
        return;
    }
    let payload = match payload {
        WirePayload::Pixels(px) => FramePayload::Pixels(px),
        WirePayload::Spikes { timesteps, words } => {
            FramePayload::Spikes { timesteps: timesteps as usize, words }
        }
    };
    // Validate against the model's frame contract *here*: a malformed
    // request costs one response, never a worker.
    if let Err(detail) = spec.validate(&payload) {
        shared.counters.bad_request.fetch_add(1, Ordering::Relaxed);
        m.counters.bad_request.fetch_add(1, Ordering::Relaxed);
        let _ = tx.send(err_frame(version, client_id,
                                  ErrorCode::BadRequest, &detail));
        return;
    }
    // Request-level APRC: predict once, tag admission with it, and
    // account the admitted/shed flow in cost units alongside counts.
    let cost = m.handle.predict_cost(&payload);
    let internal = shared.next_id.fetch_add(1, Ordering::Relaxed);
    shared.pending.lock().unwrap().insert(internal, PendingEntry {
        tx: tx.clone(),
        client_id,
        version,
        model: idx,
    });
    match m.handle.try_submit_cost(internal, payload, cost) {
        Ok(()) => {
            m.counters.cost_admitted.fetch_add(cost, Ordering::Relaxed);
        }
        Err(e) => {
            shared.pending.lock().unwrap().remove(&internal);
            let code = match e {
                SubmitError::Full { .. } => {
                    shared.counters.busy.fetch_add(1, Ordering::Relaxed);
                    m.counters.busy.fetch_add(1, Ordering::Relaxed);
                    m.counters.cost_shed
                        .fetch_add(cost, Ordering::Relaxed);
                    ErrorCode::Busy
                }
                SubmitError::Closed | SubmitError::NoWorkers => {
                    shared.counters.shutting_down
                        .fetch_add(1, Ordering::Relaxed);
                    m.counters.shutting_down
                        .fetch_add(1, Ordering::Relaxed);
                    ErrorCode::ShuttingDown
                }
            };
            let _ = tx.send(err_frame(version, client_id, code,
                                      &e.to_string()));
        }
    }
}

// -------------------------------------------------------------- router

/// Owns one model's worker event stream: matches responses back to
/// their connection by internal id, folds that model's serving stats,
/// and fails exactly the requests a dying worker had in hand.
fn router_loop(model_idx: usize,
               events: mpsc::Receiver<WorkerEvent>,
               shared: Arc<Shared>) {
    let m = &shared.models[model_idx];
    while let Ok(ev) = events.recv() {
        match ev {
            WorkerEvent::Served(r) => {
                m.stats.lock().unwrap().record(&r);
                shared.counters.served.fetch_add(1, Ordering::Relaxed);
                m.counters.served.fetch_add(1, Ordering::Relaxed);
                m.counters.cost_served
                    .fetch_add(r.predicted_cost, Ordering::Relaxed);
                let entry = shared.pending.lock().unwrap().remove(&r.id);
                if let Some(p) = entry {
                    let prediction = r.output_counts.iter().enumerate()
                        .max_by_key(|&(_, c)| *c)
                        .map(|(i, _)| i as u32)
                        .unwrap_or(0);
                    let _ = p.tx.send(WireResponse {
                        id: p.client_id,
                        body: ResponseBody::Infer {
                            prediction,
                            output_counts: r.output_counts,
                            latency_us: r.latency_us,
                            worker: r.worker as u32,
                        },
                    }.encode(p.version));
                }
            }
            WorkerEvent::Failed { worker, error, lost } => {
                m.failures.lock().unwrap()
                    .push(format!("worker {worker}: {error}"));
                fail_ids(&shared, model_idx, &lost,
                         ErrorCode::Internal, &error);
            }
            WorkerEvent::Undeliverable { lost } => {
                fail_ids(&shared, model_idx, &lost,
                         ErrorCode::ShuttingDown, "no live workers");
            }
        }
    }
    // Event stream disconnected: every worker (and the dispatcher) of
    // THIS model is gone, so none of its pending requests can ever be
    // answered — a request sitting in the queue when the last worker
    // died produced no Failed/Undeliverable event naming it. Fail this
    // model's remainder; the other models keep serving. Only when the
    // last router exits does the gateway as a whole die (loudly, via
    // drain-shutdown) — a gateway with no serviceable model must not
    // hold clients on recv forever.
    {
        let mut pending = shared.pending.lock().unwrap();
        let dead: Vec<u64> = pending.iter()
            .filter(|(_, p)| p.model == model_idx)
            .map(|(&id, _)| id)
            .collect();
        for id in dead {
            if let Some(p) = pending.remove(&id) {
                shared.counters.internal.fetch_add(1, Ordering::Relaxed);
                m.counters.internal.fetch_add(1, Ordering::Relaxed);
                let _ = p.tx.send(err_frame(
                    p.version, p.client_id, ErrorCode::Internal,
                    &format!("all workers for model '{}' exited",
                             m.name)));
            }
        }
    }
    if shared.live_routers.fetch_sub(1, Ordering::SeqCst) == 1 {
        shared.stop.store(true, Ordering::SeqCst);
    }
}

fn fail_ids(shared: &Shared, model_idx: usize, ids: &[u64],
            code: ErrorCode, detail: &str) {
    let m = &shared.models[model_idx];
    let (counter, mcounter) = match code {
        ErrorCode::ShuttingDown => (&shared.counters.shutting_down,
                                    &m.counters.shutting_down),
        ErrorCode::Busy => (&shared.counters.busy, &m.counters.busy),
        ErrorCode::BadRequest => (&shared.counters.bad_request,
                                  &m.counters.bad_request),
        ErrorCode::Internal => (&shared.counters.internal,
                                &m.counters.internal),
    };
    let mut pending = shared.pending.lock().unwrap();
    for id in ids {
        if let Some(p) = pending.remove(id) {
            counter.fetch_add(1, Ordering::Relaxed);
            mcounter.fetch_add(1, Ordering::Relaxed);
            let _ = p.tx.send(err_frame(p.version, p.client_id, code,
                                        detail));
        }
    }
}

// ------------------------------------------------------------- metrics

fn push_metric(out: &mut String, name: &str, kind: &str, v: f64) {
    use std::fmt::Write as _;
    let _ = writeln!(out, "# TYPE {name} {kind}");
    let _ = writeln!(out, "{name} {v}");
}

/// One `# TYPE` line, then one `{model="<name>"}`-labelled sample per
/// model — the single emission path for every per-model series.
fn push_labelled(out: &mut String, shared: &Shared, name: &str,
                 kind: &str, values: &[f64]) {
    use std::fmt::Write as _;
    let _ = writeln!(out, "# TYPE {name} {kind}");
    for (m, v) in shared.models.iter().zip(values) {
        let _ = writeln!(out, "{name}{{model=\"{}\"}} {v}", m.name);
    }
}

/// Prometheus-style plaintext exposition: gateway-wide counters
/// (unlabelled, as in protocol v1 days) plus per-model series labelled
/// `{model="<name>"}` — admission counters, queue, serving report and
/// latency quantiles per mounted model.
fn render_metrics(shared: &Shared) -> String {
    use std::fmt::Write as _;
    let c = shared.counters.snapshot();
    let mut out = String::with_capacity(4096);
    push_metric(&mut out, "skydiver_models_mounted", "gauge",
                shared.models.len() as f64);
    push_metric(&mut out, "skydiver_connections_accepted_total",
                "counter", c.conns_accepted as f64);
    push_metric(&mut out, "skydiver_connections_rejected_total",
                "counter", c.conns_rejected as f64);
    push_metric(&mut out, "skydiver_connections_active", "gauge",
                c.conns_active as f64);
    push_metric(&mut out, "skydiver_requests_total", "counter",
                c.requests as f64);
    push_metric(&mut out, "skydiver_served_total", "counter",
                c.served as f64);
    push_metric(&mut out, "skydiver_busy_total", "counter",
                c.busy as f64);
    push_metric(&mut out, "skydiver_bad_request_total", "counter",
                c.bad_request as f64);
    push_metric(&mut out, "skydiver_shutting_down_total", "counter",
                c.shutting_down as f64);
    push_metric(&mut out, "skydiver_internal_error_total", "counter",
                c.internal as f64);

    // One snapshot per model per scrape, so every series of one
    // exposition comes from the same instant (a scrape that locked
    // the queue once per metric could show pushed-popped != depth).
    let wall = shared.started.elapsed().as_secs_f64();
    let mcs: Vec<ModelCounterSnapshot> =
        shared.models.iter().map(|m| m.counters.snapshot()).collect();
    let queues: Vec<crate::coordinator::QueueStats> = shared.models
        .iter().map(|m| m.handle.queue_stats()).collect();
    let reports: Vec<ServingReport> = shared.models.iter()
        .map(|m| m.stats.lock().unwrap().report(wall, crate::CLOCK_HZ,
                                                m.workers))
        .collect();

    let col = |f: &dyn Fn(usize) -> f64| -> Vec<f64> {
        (0..shared.models.len()).map(f).collect()
    };
    // Per-model admission counters.
    push_labelled(&mut out, shared, "skydiver_model_requests_total",
                  "counter", &col(&|i| mcs[i].requests as f64));
    push_labelled(&mut out, shared, "skydiver_model_served_total",
                  "counter", &col(&|i| mcs[i].served as f64));
    push_labelled(&mut out, shared, "skydiver_model_busy_total",
                  "counter", &col(&|i| mcs[i].busy as f64));
    push_labelled(&mut out, shared,
                  "skydiver_model_bad_request_total", "counter",
                  &col(&|i| mcs[i].bad_request as f64));
    push_labelled(&mut out, shared,
                  "skydiver_model_internal_error_total", "counter",
                  &col(&|i| mcs[i].internal as f64));
    // Per-model queue state.
    push_labelled(&mut out, shared, "skydiver_queue_depth", "gauge",
                  &col(&|i| queues[i].depth as f64));
    push_labelled(&mut out, shared, "skydiver_queue_capacity", "gauge",
                  &col(&|i| queues[i].capacity as f64));
    push_labelled(&mut out, shared, "skydiver_queue_max_depth", "gauge",
                  &col(&|i| queues[i].max_depth as f64));
    push_labelled(&mut out, shared, "skydiver_queue_pushed_total",
                  "counter", &col(&|i| queues[i].pushed as f64));
    push_labelled(&mut out, shared, "skydiver_queue_popped_total",
                  "counter", &col(&|i| queues[i].popped as f64));
    // Cost-denominated queue state (0 = uncapped: u64::MAX as a gauge
    // would only obscure the "no cap" case).
    push_labelled(&mut out, shared, "skydiver_queue_cost_depth",
                  "gauge", &col(&|i| queues[i].cost_depth as f64));
    push_labelled(&mut out, shared, "skydiver_queue_cost_capacity",
                  "gauge", &col(&|i| {
                      if queues[i].cost_capacity == u64::MAX {
                          0.0
                      } else {
                          queues[i].cost_capacity as f64
                      }
                  }));
    // Request-level APRC series: admission flow in predicted-cost
    // units and the predictor's live calibration quality.
    push_labelled(&mut out, shared,
                  "skydiver_predicted_cost_admitted_total", "counter",
                  &col(&|i| mcs[i].cost_admitted as f64));
    push_labelled(&mut out, shared,
                  "skydiver_predicted_cost_served_total", "counter",
                  &col(&|i| mcs[i].cost_served as f64));
    push_labelled(&mut out, shared,
                  "skydiver_predicted_cost_shed_total", "counter",
                  &col(&|i| mcs[i].cost_shed as f64));
    push_labelled(&mut out, shared,
                  "skydiver_predicted_cost_mean", "gauge",
                  &col(&|i| reports[i].mean_predicted_cost));
    push_labelled(&mut out, shared,
                  "skydiver_cost_calibration_error", "gauge",
                  &col(&|i| reports[i].cost_calibration_error));
    // Per-model serving reports (histogram-backed).
    push_labelled(&mut out, shared, "skydiver_frames_served_total",
                  "counter", &col(&|i| reports[i].frames as f64));
    push_labelled(&mut out, shared, "skydiver_served_fps", "gauge",
                  &col(&|i| reports[i].served_fps));
    // Balance ratios carry the model's dispatch mode, so FIFO-vs-cost
    // comparisons read straight off the metrics endpoint.
    let _ = writeln!(out, "# TYPE skydiver_host_balance_ratio gauge");
    for (m, rep) in shared.models.iter().zip(&reports) {
        let _ = writeln!(
            out,
            "skydiver_host_balance_ratio{{model=\"{}\",dispatch=\
             \"{}\"}} {}", m.name, m.dispatch, rep.host_balance_ratio);
    }
    let _ = writeln!(out, "# TYPE skydiver_cost_balance_ratio gauge");
    for (m, rep) in shared.models.iter().zip(&reports) {
        let _ = writeln!(
            out,
            "skydiver_cost_balance_ratio{{model=\"{}\",dispatch=\
             \"{}\"}} {}", m.name, m.dispatch, rep.cost_balance_ratio);
    }
    push_labelled(&mut out, shared, "skydiver_sim_fps", "gauge",
                  &col(&|i| reports[i].sim_fps));
    push_labelled(&mut out, shared, "skydiver_sim_energy_uj_mean",
                  "gauge", &col(&|i| reports[i].mean_energy_uj));
    let _ = writeln!(out, "# TYPE skydiver_latency_us summary");
    for (m, rep) in shared.models.iter().zip(&reports) {
        for (quant, v) in [("0.5", rep.p50_us), ("0.95", rep.p95_us),
                           ("0.99", rep.p99_us)] {
            let _ = writeln!(
                out,
                "skydiver_latency_us{{model=\"{}\",quantile=\
                 \"{quant}\"}} {v}", m.name);
        }
    }
    let _ = writeln!(out, "# TYPE skydiver_worker_frames_total counter");
    for (m, rep) in shared.models.iter().zip(&reports) {
        for (i, n) in rep.per_worker.iter().enumerate() {
            let _ = writeln!(
                out,
                "skydiver_worker_frames_total{{model=\"{}\",\
                 worker=\"{i}\"}} {n}", m.name);
        }
    }
    out
}
