//! Energy and FPGA-resource models (DESIGN.md §2 substitutions for the
//! paper's measured power and Vivado utilisation reports).

mod energy;
mod resources;

pub use energy::{EnergyBreakdown, EnergyModel};
pub use resources::{resource_table, ResourceModel, ResourceUsage};
