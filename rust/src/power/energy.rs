//! Event-count energy model.
//!
//! The simulator counts *events* exactly (synaptic adds, weight fetches,
//! VMEM read-modify-writes, state-scan words, DMA bytes); this model
//! attaches per-event energies plus a static-power term.
//!
//! Calibration: per-event constants are standard 28 nm-class FPGA costs
//! (LUT-fabric add, 18 Kb BRAM access) chosen so the paper's operating
//! point — ~1 MSOp/frame classification at 42.4 uJ/image and 0.96 W
//! on-chip (Table I) — is reproduced by the default config; the *ratios*
//! between configurations are then driven entirely by the simulator's
//! measured counts. See EXPERIMENTS.md §Table I.



use crate::sim::FrameReport;

/// Per-event energies in picojoules + static power in watts.
#[derive(Debug, Clone, Copy)]
pub struct EnergyModel {
    /// One synaptic add on the LUT fabric (no DSPs — binary spikes).
    pub pj_synop: f64,
    /// One weight word fetched from a BRAM bank.
    pub pj_weight_read: f64,
    /// One membrane-potential read-modify-write.
    pub pj_vmem_rmw: f64,
    /// One 64-bit neuron-state word scanned.
    pub pj_state_word: f64,
    /// One DMA byte moved.
    pub pj_dma_byte: f64,
    /// Static + clock-tree power in watts.
    pub static_w: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        Self {
            pj_synop: 4.0,
            pj_weight_read: 12.0,
            pj_vmem_rmw: 18.0,
            pj_state_word: 8.0,
            pj_dma_byte: 20.0,
            static_w: 0.20,
        }
    }
}

/// Energy of one frame, split by source.
#[derive(Debug, Clone, Default)]
pub struct EnergyBreakdown {
    pub synop_j: f64,
    pub weight_j: f64,
    pub vmem_j: f64,
    pub state_j: f64,
    pub dma_j: f64,
    pub static_j: f64,
    pub total_j: f64,
    /// Mean power over the frame in watts.
    pub mean_w: f64,
}

impl EnergyModel {
    /// Energy of a simulated frame at `clock_hz`.
    pub fn frame_energy(&self, f: &FrameReport, clock_hz: f64)
                        -> EnergyBreakdown {
        const PJ: f64 = 1e-12;
        let secs = f.total_cycles as f64 / clock_hz;
        let mut b = EnergyBreakdown {
            synop_j: f.synops as f64 * self.pj_synop * PJ,
            weight_j: f.weight_reads as f64 * self.pj_weight_read * PJ,
            vmem_j: f.vmem_rmw as f64 * self.pj_vmem_rmw * PJ,
            state_j: f.state_reads as f64 * self.pj_state_word * PJ,
            dma_j: f.dma_bytes as f64 * self.pj_dma_byte * PJ,
            static_j: self.static_w * secs,
            ..Default::default()
        };
        b.total_j = b.synop_j + b.weight_j + b.vmem_j + b.state_j
            + b.dma_j + b.static_j;
        b.mean_w = b.total_j / secs.max(1e-12);
        b
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frame(synops: u64, cycles: u64) -> FrameReport {
        FrameReport {
            synops,
            weight_reads: synops,
            vmem_rmw: synops,
            state_reads: 1000,
            dma_bytes: 4000,
            total_cycles: cycles,
            ..Default::default()
        }
    }

    #[test]
    fn energy_scales_with_ops() {
        let m = EnergyModel::default();
        let e1 = m.frame_energy(&frame(1_000_000, 200_000), 200e6);
        let e2 = m.frame_energy(&frame(2_000_000, 200_000), 200e6);
        assert!(e2.total_j > e1.total_j);
        assert!((e2.synop_j / e1.synop_j - 2.0).abs() < 1e-9);
        // Static term identical at identical latency.
        assert!((e2.static_j - e1.static_j).abs() < 1e-15);
    }

    #[test]
    fn paper_operating_point_magnitude() {
        // ~1 MSOp classification frame in ~8850 cycles (22.6 KFPS):
        // energy must land in the tens of microjoules (paper: 42.4 uJ).
        let m = EnergyModel::default();
        let e = m.frame_energy(&frame(1_000_000, 8_850), 200e6);
        let uj = e.total_j * 1e6;
        assert!((10.0..120.0).contains(&uj), "got {uj} uJ");
    }

    #[test]
    fn mean_power_magnitude() {
        // Sustained heavy traffic should be around the paper's ~1 W.
        let m = EnergyModel::default();
        let e = m.frame_energy(&frame(1_000_000, 8_850), 200e6);
        assert!((0.3..3.0).contains(&e.mean_w), "got {} W", e.mean_w);
    }
}
