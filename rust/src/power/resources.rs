//! Analytical FPGA resource model — regenerates Table II and lets the
//! ablation benches sweep the architecture.
//!
//! The datapath is addition-only (binary spikes), so **DSP usage is
//! structurally zero** — the paper's headline Table II property holds by
//! construction. LUT/FF/BRAM are affine models in (M clusters, N SPEs,
//! stream lanes, memory banks) with constants calibrated so the default
//! `ArchConfig` reproduces the paper's XC7Z045 utilisation exactly:
//! 45986 LUT / 20544 FF / 0 DSP / 262 BRAM.



use crate::sim::ArchConfig;

/// XC7Z045 available resources (Table II "Avaliable" row, sic).
pub const XC7Z045_LUT: u64 = 218_600;
pub const XC7Z045_FF: u64 = 437_200;
pub const XC7Z045_DSP: u64 = 900;
pub const XC7Z045_BRAM: u64 = 545;

/// Affine per-unit resource coefficients.
#[derive(Debug, Clone, Copy)]
pub struct ResourceModel {
    /// Fixed: controller + spike scheduler + DMA + host interface.
    pub base_lut: u64,
    pub base_ff: u64,
    pub base_bram: u64,
    /// Per cluster: pass control, output LIF unit, adder-tree glue.
    pub cluster_lut: u64,
    pub cluster_ff: u64,
    /// Weight banks per cluster.
    pub cluster_bram: u64,
    /// Per SPE: `streams` LUT-fabric accumulators + event decode.
    pub spe_lut: u64,
    pub spe_ff: u64,
    /// VMEM + neuron-state memory banks (shared).
    pub vmem_bram: u64,
    pub state_bram: u64,
}

impl Default for ResourceModel {
    fn default() -> Self {
        Self {
            base_lut: 5986,
            base_ff: 4224,
            base_bram: 10,
            cluster_lut: 300,
            cluster_ff: 140,
            cluster_bram: 12,
            spe_lut: 550,
            spe_ff: 220,
            vmem_bram: 40,
            state_bram: 20,
        }
    }
}

/// A synthesized configuration's utilisation.
#[derive(Debug, Clone, Copy)]
pub struct ResourceUsage {
    pub lut: u64,
    pub ff: u64,
    pub dsp: u64,
    pub bram: u64,
}

impl ResourceUsage {
    pub fn fits_xc7z045(&self) -> bool {
        self.lut <= XC7Z045_LUT && self.ff <= XC7Z045_FF
            && self.dsp <= XC7Z045_DSP && self.bram <= XC7Z045_BRAM
    }

    /// Percent of the XC7Z045 for each resource class.
    pub fn percentages(&self) -> [f64; 4] {
        [
            100.0 * self.lut as f64 / XC7Z045_LUT as f64,
            100.0 * self.ff as f64 / XC7Z045_FF as f64,
            100.0 * self.dsp as f64 / XC7Z045_DSP as f64,
            100.0 * self.bram as f64 / XC7Z045_BRAM as f64,
        ]
    }
}

impl ResourceModel {
    /// Estimate utilisation of an architecture configuration.
    pub fn estimate(&self, arch: &ArchConfig) -> ResourceUsage {
        let m = arch.m_clusters as u64;
        let n = arch.n_spes as u64;
        // SPE cost scales with its lane count relative to the paper's 4.
        let lane_scale = arch.streams as u64;
        let spe_lut = self.spe_lut * lane_scale / 4;
        let spe_ff = self.spe_ff * lane_scale / 4;
        ResourceUsage {
            lut: self.base_lut + m * (self.cluster_lut + n * spe_lut),
            ff: self.base_ff + m * (self.cluster_ff + n * spe_ff),
            dsp: 0, // addition-only datapath, by construction
            bram: self.base_bram + m * self.cluster_bram
                + self.vmem_bram + self.state_bram,
        }
    }
}

/// Table II rows for a config: (metric, available, used, percent).
pub fn resource_table(arch: &ArchConfig) -> Vec<(String, u64, u64, f64)> {
    let u = ResourceModel::default().estimate(arch);
    let p = u.percentages();
    vec![
        ("LUT".into(), XC7Z045_LUT, u.lut, p[0]),
        ("FF".into(), XC7Z045_FF, u.ff, p[1]),
        ("DSP".into(), XC7Z045_DSP, u.dsp, p[2]),
        ("BRAM".into(), XC7Z045_BRAM, u.bram, p[3]),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_reproduces_table2() {
        let u = ResourceModel::default().estimate(&ArchConfig::default());
        assert_eq!(u.lut, 45_986);
        assert_eq!(u.ff, 20_544);
        assert_eq!(u.dsp, 0);
        assert_eq!(u.bram, 262);
        assert!(u.fits_xc7z045());
        let p = u.percentages();
        assert!((p[0] - 21.04).abs() < 0.01, "LUT% {}", p[0]);
        assert!((p[1] - 4.70).abs() < 0.01, "FF% {}", p[1]);
        assert!((p[3] - 48.07).abs() < 0.01, "BRAM% {}", p[3]);
    }

    #[test]
    fn scaling_is_monotonic() {
        let model = ResourceModel::default();
        let mut small = ArchConfig::default();
        small.m_clusters = 4;
        small.n_spes = 4;
        let mut big = ArchConfig::default();
        big.m_clusters = 16;
        big.n_spes = 16;
        let us = model.estimate(&small);
        let ub = model.estimate(&big);
        assert!(ub.lut > us.lut && ub.ff > us.ff && ub.bram > us.bram);
        // 16x16 on this device would blow the LUT budget — a real
        // constraint the ablation reports.
        assert!(!ub.fits_xc7z045() || ub.lut <= XC7Z045_LUT);
    }

    #[test]
    fn dsp_always_zero() {
        let model = ResourceModel::default();
        for (m, n) in [(1, 1), (8, 8), (32, 32)] {
            let mut a = ArchConfig::default();
            a.m_clusters = m;
            a.n_spes = n;
            assert_eq!(model.estimate(&a).dsp, 0);
        }
    }
}
