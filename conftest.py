"""Repo-root pytest shim: make `python/` importable so
`pytest python/tests/` works from the repository root."""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).parent / "python"))
