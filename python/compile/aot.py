"""AOT build: train -> convert -> lower to HLO text -> artifacts/.

Run once by ``make artifacts``; the rust binary is self-contained after.

Interchange format is HLO *text*, NOT a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids, so text round-trips cleanly (see /opt/xla-example).

Artifacts written per network variant (classifier/segmenter x aprc/plain):

* ``<name>.step.hlo.txt``   — one SNN timestep: (s_in, vmem_0..L) ->
  (spikes_0..L, vmem'_0..L), weights baked as constants, Pallas kernels
  (interpret mode) lowered inline. The rust runtime drives T steps and
  harvests the per-layer spike traces for the cycle-level simulator.
* ``<name>.weights.bin/json`` — the same weights for the rust-side
  scheduler (APRC filter magnitudes) and simulator.
* ``meta.json``             — dataset seeds/hashes, eval metrics, encoding
  cross-check hashes, variant inventory.
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import datasets, model, train


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def export_step_hlo(cfg: model.NetConfig, params: dict, out: Path) -> int:
    """Lower the per-timestep network step to HLO text. Returns #bytes.

    Weights are *parameters*, not baked constants: ``as_hlo_text`` elides
    large literals (``constant({...})``), so baked weights would not
    round-trip through the text format. Argument order (matches the rust
    runtime and the ``layers`` list in the weights json):

        s_in, vmem_0..vmem_L, conv_w_0..conv_w_{n-1}[, dense_w, dense_b]

    Outputs: spikes_0..spikes_L, vmem'_0..vmem'_L (flat tuple).
    """
    nconv = len(params["conv"])

    def step(s_in, *rest):
        nv = cfg.num_layers()
        vmems = rest[:nv]
        ws = list(rest[nv:nv + nconv])
        p = {"conv": ws, "dense": None}
        if cfg.dense_out is not None:
            p["dense"] = {"w": rest[nv + nconv], "b": rest[nv + nconv + 1]}
        spikes, new_vmems = model.network_step(p, cfg, s_in, vmems,
                                               use_pallas=True)
        return spikes + new_vmems

    specs = [jax.ShapeDtypeStruct((cfg.in_ch, cfg.in_h, cfg.in_w),
                                  jnp.float32)]
    specs += [jax.ShapeDtypeStruct(s, jnp.float32)
              for s in cfg.vmem_shapes()]
    specs += [jax.ShapeDtypeStruct(w.shape, jnp.float32)
              for w in params["conv"]]
    if cfg.dense_out is not None:
        specs += [jax.ShapeDtypeStruct(params["dense"]["w"].shape,
                                       jnp.float32),
                  jax.ShapeDtypeStruct(params["dense"]["b"].shape,
                                       jnp.float32)]
    lowered = jax.jit(step).lower(*specs)
    text = to_hlo_text(lowered)
    out.write_text(text)
    return len(text)


def load_weights(out_dir: Path, name: str) -> tuple[dict, dict] | None:
    """Load previously trained weights (inverse of train.save_weights)."""
    jpath = out_dir / f"{name}.weights.json"
    bpath = out_dir / f"{name}.weights.bin"
    if not (jpath.exists() and bpath.exists()):
        return None
    meta = json.loads(jpath.read_text())
    blob = np.frombuffer(bpath.read_bytes(), dtype="<f4")
    if blob.size != meta["total_floats"]:
        return None
    params: dict = {"conv": [], "dense": None}
    dense_w = dense_b = None
    for layer in meta["layers"]:
        n = int(np.prod(layer["shape"]))
        arr = jnp.asarray(blob[layer["offset"]:layer["offset"] + n]
                          .reshape(layer["shape"]))
        if layer["kind"] == "conv":
            params["conv"].append(arr)
        elif layer["kind"] == "dense_w":
            dense_w = arr
        elif layer["kind"] == "dense_b":
            dense_b = arr
    if dense_w is not None:
        params["dense"] = {"w": dense_w, "b": dense_b}
    return params, meta


def build_variant(cfg: model.NetConfig, out_dir: Path, *, quick: bool,
                  retrain: bool, log=print) -> dict:
    """Train (or reuse), convert, evaluate, serialise, export one variant."""
    cached = None if retrain else load_weights(out_dir, cfg.name)
    if cached is not None:
        log(f"[{cfg.name}] reusing cached weights")
        snn_params, meta = cached
        extra = {k: meta[k] for k in ("ann_metric", "snn_metric",
                                      "seg_rate_threshold") if k in meta}
        lambdas = meta["lambdas"]
    else:
        t0 = time.time()
        if cfg.dense_out is not None:
            ann = train.train_classifier(cfg, epochs=2 if quick else 5,
                                         log=log)
            acc = train.eval_ann_classifier(ann, cfg)
            log(f"[{cfg.name}] ANN accuracy: {acc:.4f}")
            imgs, _ = datasets.gen_digits(train.DIGITS_TRAIN_SEED, 512)
            calib = jnp.asarray(imgs, jnp.float32)[:, None] / 255.0
            snn_params, lambdas = train.convert_to_snn(ann, cfg, calib)
            snn_acc = train.eval_snn_classifier(
                snn_params, cfg, 128 if quick else 512)
            log(f"[{cfg.name}] SNN accuracy: {snn_acc:.4f}")
            extra = {"ann_metric": acc, "snn_metric": snn_acc}
        else:
            ann = train.train_segmenter(cfg, epochs=1 if quick else 3,
                                        log=log)
            imgs, _ = datasets.gen_road_scenes(train.ROADS_TRAIN_SEED, 16)
            calib = jnp.asarray(imgs, jnp.float32).transpose(0, 3, 1, 2) / 255.0
            snn_params, lambdas = train.convert_to_snn(ann, cfg, calib)
            thr, iou = train.calibrate_seg_threshold(
                snn_params, cfg, 4 if quick else 8)
            log(f"[{cfg.name}] SNN IoU: {iou:.4f} @ rate>={thr}")
            extra = {"snn_metric": iou, "seg_rate_threshold": thr}
        log(f"[{cfg.name}] trained+converted in {time.time() - t0:.1f}s")
        train.save_weights(out_dir, cfg, snn_params, lambdas, extra)

    hlo_path = out_dir / f"{cfg.name}.step.hlo.txt"
    nbytes = export_step_hlo(cfg, snn_params, hlo_path)
    log(f"[{cfg.name}] wrote {hlo_path.name} ({nbytes / 1e6:.1f} MB)")
    mags = [[float(x) for x in model.filter_magnitudes(snn_params, li)]
            for li in range(len(cfg.convs))]
    return {"name": cfg.name, "hlo": hlo_path.name,
            "timesteps": cfg.timesteps, "filter_magnitudes": mags, **extra}


def encoding_crosscheck() -> dict:
    """Hash a known encoded spike train so rust/src/snn can verify its
    port of encode_phased bit-for-bit."""
    imgs, _ = datasets.gen_digits(train.DIGITS_TEST_SEED, 1)
    x = jnp.asarray(imgs[0], jnp.float32)[None] / 255.0  # (1, 28, 28)
    spikes = np.asarray(model.encode_phased(x, 24), dtype=np.uint8)
    return {"image_seed": train.DIGITS_TEST_SEED, "timesteps": 24,
            "spike_count": int(spikes.sum()),
            "fnv1a64": f"{datasets.fnv1a64(spikes.tobytes()):016x}"}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="artifacts directory")
    ap.add_argument("--quick", action="store_true",
                    help="reduced training (CI smoke)")
    ap.add_argument("--retrain", action="store_true",
                    help="ignore cached weights")
    ap.add_argument("--only", default=None,
                    help="build a single variant by name")
    args = ap.parse_args()
    out_dir = Path(args.out).resolve()
    out_dir.mkdir(parents=True, exist_ok=True)

    configs = [
        model.classifier_config(aprc=True),
        model.classifier_config(aprc=False),
        model.segmenter_config(aprc=True),
        model.segmenter_config(aprc=False),
    ]
    if args.only:
        configs = [c for c in configs if c.name == args.only]

    variants = []
    for cfg in configs:
        variants.append(build_variant(cfg, out_dir, quick=args.quick,
                                      retrain=args.retrain))

    meta = {
        "paper": "Skydiver (TCAD 2022), DOI 10.1109/TCAD.2022.3158834",
        "datasets": {
            "digits": {
                "train_seed": train.DIGITS_TRAIN_SEED,
                "test_seed": train.DIGITS_TEST_SEED,
                "train_n": train.DIGITS_TRAIN_N,
                "test_n": train.DIGITS_TEST_N,
                "test_hash16": f"{datasets.digits_hash(train.DIGITS_TEST_SEED, 16):016x}",
            },
            "roads": {
                "train_seed": train.ROADS_TRAIN_SEED,
                "test_seed": train.ROADS_TEST_SEED,
                "train_n": train.ROADS_TRAIN_N,
                "test_n": train.ROADS_TEST_N,
                "test_hash2": f"{datasets.road_scenes_hash(train.ROADS_TEST_SEED, 2):016x}",
            },
        },
        "encoding_crosscheck": encoding_crosscheck(),
        "variants": variants,
    }
    (out_dir / "meta.json").write_text(json.dumps(meta, indent=1))
    print(f"wrote {out_dir / 'meta.json'}")


if __name__ == "__main__":
    sys.exit(main())
