"""Training + ANN->SNN conversion (build-time only; never at serve time).

Pipeline per network variant:

1. Train the ReLU twin (``model.ann_forward``) with Adam on the synthetic
   dataset (DESIGN.md §2 substitutions).
2. Threshold-balanced conversion (Diehl et al. style data-based
   normalisation): scale layer l by lambda_{l-1}/lambda_l where lambda_l
   is the p99.9 activation over a calibration batch, so every hidden
   activation maps into [0,1] spike-rate units with vth = 1.
3. Serialise weights to ``artifacts/<name>.weights.bin`` (raw little-endian
   f32) + ``artifacts/<name>.weights.json`` (shapes/offsets/thresholds) for
   the rust side.

The classifier reproduces the paper's 98.5 % accuracy claim (on the
synthetic test split); the segmenter reports IoU on held-out road scenes.
"""

from __future__ import annotations

import functools
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from . import datasets, model

DIGITS_TRAIN_SEED = 0xD16175
DIGITS_TEST_SEED = 0x7E57D161
ROADS_TRAIN_SEED = 0x80AD5
ROADS_TEST_SEED = 0x7E570AD5

DIGITS_TRAIN_N = 12000
DIGITS_TEST_N = 2000
ROADS_TRAIN_N = 192
ROADS_TEST_N = 32


# --------------------------------------------------------------------------
# Minimal Adam (no optax dependency)
# --------------------------------------------------------------------------

def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params),
            "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g,
                               state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g,
                               state["v"], grads)
    tf = t.astype(jnp.float32)
    def upd(p, m, v):
        mhat = m / (1 - b1 ** tf)
        vhat = v / (1 - b2 ** tf)
        return p - lr * mhat / (jnp.sqrt(vhat) + eps)
    new_params = jax.tree_util.tree_map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "t": t}


# --------------------------------------------------------------------------
# Losses / training loops
# --------------------------------------------------------------------------

def _ce_loss(params, cfg, x, y):
    logits = model.ann_forward(params, cfg, x)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def _crop_to_input(cfg: model.NetConfig, scores: jax.Array) -> jax.Array:
    """Full-pad (APRC) nets grow each conv by 2*pad - R + 1; crop the
    output back to the input geometry for the loss / mask decision."""
    _, h, w = cfg.feature_sizes()[-1]
    dh = (h - cfg.in_h) // 2
    dw = (w - cfg.in_w) // 2
    if dh == 0 and dw == 0:
        return scores
    return scores[..., dh:dh + cfg.in_h, dw:dw + cfg.in_w]


def _bce_loss(params, cfg, x, mask):
    scores = model.ann_forward(params, cfg, x)[:, 0]  # (B, H', W')
    scores = _crop_to_input(cfg, scores)
    z = scores
    # numerically stable BCE-with-logits
    loss = jnp.maximum(z, 0) - z * mask + jnp.log1p(jnp.exp(-jnp.abs(z)))
    return loss.mean()


def train_classifier(cfg: model.NetConfig, *, epochs: int = 5,
                     batch: int = 128, lr: float = 1e-3, seed: int = 7,
                     log=print) -> dict:
    imgs, labels = datasets.gen_digits(DIGITS_TRAIN_SEED, DIGITS_TRAIN_N)
    x_all = jnp.asarray(imgs, jnp.float32)[:, None] / 255.0
    y_all = jnp.asarray(labels, jnp.int32)
    params = model.init_params(cfg, jax.random.PRNGKey(seed))
    opt = adam_init(params)

    @jax.jit
    def step(params, opt, x, y):
        loss, grads = jax.value_and_grad(_ce_loss)(params, cfg, x, y)
        params, opt = adam_update(params, grads, opt, lr=lr)
        return params, opt, loss

    nb = DIGITS_TRAIN_N // batch
    rng = np.random.default_rng(seed)
    for ep in range(epochs):
        perm = rng.permutation(DIGITS_TRAIN_N)
        t0, tot = time.time(), 0.0
        for b in range(nb):
            idx = perm[b * batch:(b + 1) * batch]
            params, opt, loss = step(params, opt, x_all[idx], y_all[idx])
            tot += float(loss)
        log(f"[{cfg.name}] epoch {ep}: loss={tot / nb:.4f} "
            f"({time.time() - t0:.1f}s)")
    return params


def train_segmenter(cfg: model.NetConfig, *, epochs: int = 6,
                    batch: int = 8, lr: float = 2e-3, seed: int = 9,
                    log=print) -> dict:
    imgs, masks = datasets.gen_road_scenes(ROADS_TRAIN_SEED, ROADS_TRAIN_N)
    # (B, 3, H, W) in [0,1]
    x_all = jnp.asarray(imgs, jnp.float32).transpose(0, 3, 1, 2) / 255.0
    m_all = jnp.asarray(masks, jnp.float32)
    params = model.init_params(cfg, jax.random.PRNGKey(seed))
    opt = adam_init(params)

    @jax.jit
    def step(params, opt, x, m):
        loss, grads = jax.value_and_grad(_bce_loss)(params, cfg, x, m)
        params, opt = adam_update(params, grads, opt, lr=lr)
        return params, opt, loss

    nb = ROADS_TRAIN_N // batch
    rng = np.random.default_rng(seed)
    for ep in range(epochs):
        perm = rng.permutation(ROADS_TRAIN_N)
        t0, tot = time.time(), 0.0
        for b in range(nb):
            idx = perm[b * batch:(b + 1) * batch]
            params, opt, loss = step(params, opt, x_all[idx], m_all[idx])
            tot += float(loss)
        log(f"[{cfg.name}] epoch {ep}: loss={tot / nb:.4f} "
            f"({time.time() - t0:.1f}s)")
    return params


# --------------------------------------------------------------------------
# ANN -> SNN conversion (threshold balancing)
# --------------------------------------------------------------------------

def convert_to_snn(params: dict, cfg: model.NetConfig, calib_x: jax.Array,
                   pct: float = 99.9) -> tuple[dict, list[float]]:
    """Data-based weight normalisation. Returns (snn params, lambdas).

    lambda_0 = 1 (inputs already in [0,1]); hidden layer l is scaled by
    lambda_{l-1}/lambda_l so hidden spike rates track ReLU activations in
    [0,1]. The *output* layer (dense logits or the segmenter's last conv)
    is scaled by lambda_{L-1}/lambda_out with lambda_out = pct-percentile
    of |score|: per-step input current = score/lambda_out in [-1, 1], so
    output spike rates encode the scores without saturating at vth=1
    (uniform scaling preserves argmax / mask ordering). The recorded
    lambdas list carries lambda_out last, so the transform is invertible.
    """
    logits, acts = model.ann_forward(params, cfg, calib_x, collect=True)
    lambdas = [max(float(jnp.percentile(a, pct)), 1e-6) for a in acts]
    lam_out = max(float(jnp.percentile(jnp.abs(logits), pct)), 1e-6)
    new = {"conv": [], "dense": None}
    prev = 1.0
    for li, w in enumerate(params["conv"]):
        is_hidden = li < len(lambdas)
        if is_hidden:
            lam = lambdas[li]
            new["conv"].append(w * (prev / lam))
            prev = lam
        else:  # segmenter output conv
            new["conv"].append(w * (prev / lam_out))
    if params["dense"] is not None:
        d = params["dense"]
        # Bias is a per-step current: spread the trained bias over T steps.
        new["dense"] = {"w": d["w"] * (prev / lam_out),
                        "b": d["b"] / (lam_out * cfg.timesteps)}
    return new, lambdas + [lam_out]


# --------------------------------------------------------------------------
# Evaluation
# --------------------------------------------------------------------------

def eval_ann_classifier(params, cfg, n: int = DIGITS_TEST_N) -> float:
    imgs, labels = datasets.gen_digits(DIGITS_TEST_SEED, n)
    x = jnp.asarray(imgs, jnp.float32)[:, None] / 255.0
    logits = jax.jit(lambda p, x: model.ann_forward(p, cfg, x))(params, x)
    return float((jnp.argmax(logits, 1) == jnp.asarray(labels)).mean())


def snn_classify(params, cfg, x01: jax.Array, *, use_pallas=False):
    """x01: (B, 1, 28, 28). Returns predicted labels via output spike
    counts over cfg.timesteps."""

    def one(xi):
        train = model.encode_phased(xi, cfg.timesteps)
        counts = model.run_snn(params, cfg, train, use_pallas=use_pallas)
        return jnp.argmax(counts[-1])

    return jax.jit(jax.vmap(one))(x01)


def eval_snn_classifier(params, cfg, n: int = 512, *,
                        use_pallas=False) -> float:
    imgs, labels = datasets.gen_digits(DIGITS_TEST_SEED, n)
    x = jnp.asarray(imgs, jnp.float32)[:, None] / 255.0
    pred = snn_classify(params, cfg, x, use_pallas=use_pallas)
    return float((pred == jnp.asarray(labels[:n])).mean())


def snn_segment_counts(params, cfg, x01: jax.Array, *, use_pallas=False):
    """x01: (3, H, W) -> output-layer spike counts cropped to input geom."""
    train = model.encode_phased(x01, cfg.timesteps)
    counts = model.run_snn(params, cfg, train, use_pallas=use_pallas)
    return _crop_to_input(cfg, counts[-1][0])


def _seg_counts_and_masks(params, cfg, n: int, use_pallas: bool):
    imgs, masks = datasets.gen_road_scenes(ROADS_TEST_SEED, n)
    x = jnp.asarray(imgs, jnp.float32).transpose(0, 3, 1, 2) / 255.0
    fn = jax.jit(jax.vmap(functools.partial(
        snn_segment_counts, params, cfg, use_pallas=use_pallas)))
    return fn(x), jnp.asarray(masks, bool)


def _iou(pred: jax.Array, gt: jax.Array) -> float:
    inter = (pred & gt).sum(axis=(1, 2))
    union = (pred | gt).sum(axis=(1, 2))
    return float((inter / jnp.maximum(union, 1)).mean())


def eval_snn_segmenter(params, cfg, n: int = 8, *,
                       rate_threshold: float = 0.5,
                       use_pallas=False) -> float:
    """Mean IoU of (spike count / T >= rate_threshold) vs ground truth."""
    counts, gt = _seg_counts_and_masks(params, cfg, n, use_pallas)
    return _iou(counts / cfg.timesteps >= rate_threshold, gt)


def calibrate_seg_threshold(params, cfg, n: int = 8,
                            use_pallas=False) -> tuple[float, float]:
    """Pick the spike-rate decision threshold maximising IoU on a
    calibration set (counts computed once). Returns (threshold, iou)."""
    counts, gt = _seg_counts_and_masks(params, cfg, n, use_pallas)
    best = (0.5, -1.0)
    for thr in [0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7]:
        iou = _iou(counts / cfg.timesteps >= thr, gt)
        if iou > best[1]:
            best = (thr, iou)
    return best


# --------------------------------------------------------------------------
# Serialisation (rust/src/snn/weights.rs is the reader)
# --------------------------------------------------------------------------

def save_weights(out_dir: Path, cfg: model.NetConfig, params: dict,
                 lambdas: list[float], extra: dict) -> dict:
    """Write <name>.weights.bin (raw LE f32) + <name>.weights.json."""
    out_dir.mkdir(parents=True, exist_ok=True)
    arrays: list[np.ndarray] = []
    layers = []
    offset = 0

    def push(kind: str, arr: np.ndarray, **kw):
        nonlocal offset
        arr = np.ascontiguousarray(arr, dtype="<f4")
        layers.append({"kind": kind, "shape": list(arr.shape),
                       "offset": offset, **kw})
        arrays.append(arr)
        offset += arr.size

    for li, w in enumerate(params["conv"]):
        push("conv", np.asarray(w), layer=li, pad=cfg.pad)
    if params["dense"] is not None:
        push("dense_w", np.asarray(params["dense"]["w"]),
             layer=len(params["conv"]))
        push("dense_b", np.asarray(params["dense"]["b"]),
             layer=len(params["conv"]))

    blob = b"".join(a.tobytes() for a in arrays)
    bin_path = out_dir / f"{cfg.name}.weights.bin"
    bin_path.write_bytes(blob)

    meta = {
        "name": cfg.name,
        "aprc": cfg.aprc,
        "pad": cfg.pad,
        "vth": cfg.vth,
        "timesteps": cfg.timesteps,
        "in_shape": [cfg.in_ch, cfg.in_h, cfg.in_w],
        "feature_sizes": [list(s) for s in cfg.feature_sizes()],
        "dense_out": cfg.dense_out,
        "total_floats": offset,
        "lambdas": lambdas,
        "layers": layers,
        "blob_fnv1a64": f"{datasets.fnv1a64(blob):016x}",
        **extra,
    }
    (out_dir / f"{cfg.name}.weights.json").write_text(
        json.dumps(meta, indent=1))
    return meta
