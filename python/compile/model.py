"""L2: JAX definitions of the paper's two spiking networks.

* Classifier — ``28x28-16c-32c-8c-10`` (paper §IV) on the synthetic digit
  dataset (MNIST substitute).
* Segmenter  — ``160x80x3-8C3-16C3-32C3-32C3-16C3-1C3-160x80x1`` (paper
  §IV, MLND-Capstone substitute road scenes).

Each network exists in two convolution variants:

* ``aprc``  — the paper's APRC-modified convolution: pad = R-1 per side
  (a *full* convolution, stride 1). Eq. 5 then makes the summed membrane
  update of an output channel **exactly** filter_magnitude x input_sum,
  so channel spikerates become approximately proportional to the filter
  magnitudes that the offline scheduler knows.
* ``plain`` — the ordinary same-padded convolution (pad = R//2), used as
  the "without APRC" baseline of Fig. 6(a)/Fig. 7.

The per-timestep *step* function (input spikes + membrane state in,
per-layer output spikes + new state out) is what ``aot.py`` lowers to HLO
text for the rust runtime; Python is never on the request path.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from .kernels.spiking_conv import spiking_conv_step
from .kernels.spiking_dense import spiking_dense_step
from .kernels import ref as kref


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    cin: int
    cout: int
    r: int


@dataclasses.dataclass(frozen=True)
class NetConfig:
    """Static description of one network variant."""

    name: str
    in_ch: int
    in_h: int
    in_w: int
    convs: tuple[ConvSpec, ...]
    dense_out: Optional[int]     # classifier: 10; segmenter: None
    pad: int                     # R-1 (APRC) or R//2 (plain)
    vth: float
    timesteps: int

    @property
    def aprc(self) -> bool:
        return self.pad == self.convs[0].r - 1

    def feature_sizes(self) -> list[tuple[int, int, int]]:
        """(C, H, W) of every conv layer *output*."""
        sizes = []
        h, w = self.in_h, self.in_w
        for cs in self.convs:
            h = h + 2 * self.pad - cs.r + 1
            w = w + 2 * self.pad - cs.r + 1
            sizes.append((cs.cout, h, w))
        return sizes

    def dense_in(self) -> int:
        c, h, w = self.feature_sizes()[-1]
        return c * h * w

    def vmem_shapes(self) -> list[tuple[int, ...]]:
        shapes: list[tuple[int, ...]] = [tuple(s) for s in
                                         self.feature_sizes()]
        if self.dense_out is not None:
            shapes.append((self.dense_out,))
        return shapes

    def num_layers(self) -> int:
        return len(self.convs) + (1 if self.dense_out is not None else 0)


def classifier_config(aprc: bool, timesteps: int = 24) -> NetConfig:
    r = 3
    return NetConfig(
        name="classifier_aprc" if aprc else "classifier_plain",
        in_ch=1, in_h=28, in_w=28,
        convs=(ConvSpec(1, 16, r), ConvSpec(16, 32, r), ConvSpec(32, 8, r)),
        dense_out=10,
        pad=r - 1 if aprc else r // 2,
        vth=1.0,
        timesteps=timesteps,
    )


def segmenter_config(aprc: bool, timesteps: int = 50) -> NetConfig:
    r = 3
    return NetConfig(
        name="segmenter_aprc" if aprc else "segmenter_plain",
        in_ch=3, in_h=80, in_w=160,
        convs=(ConvSpec(3, 8, r), ConvSpec(8, 16, r), ConvSpec(16, 32, r),
               ConvSpec(32, 32, r), ConvSpec(32, 16, r), ConvSpec(16, 1, r)),
        dense_out=None,
        pad=r - 1 if aprc else r // 2,
        vth=1.0,
        timesteps=timesteps,
    )


def config_by_name(name: str, timesteps: int | None = None) -> NetConfig:
    base = {
        "classifier_aprc": lambda: classifier_config(True),
        "classifier_plain": lambda: classifier_config(False),
        "segmenter_aprc": lambda: segmenter_config(True),
        "segmenter_plain": lambda: segmenter_config(False),
    }[name]()
    if timesteps is not None:
        base = dataclasses.replace(base, timesteps=timesteps)
    return base


# --------------------------------------------------------------------------
# Parameters
# --------------------------------------------------------------------------

def init_params(cfg: NetConfig, key: jax.Array) -> dict:
    """He-style init. Conv layers are bias-free (keeps the Eq. 5
    proportionality exact; the paper's Eq. 2 bias is absorbed into the
    dense layer only)."""
    params: dict = {"conv": [], "dense": None}
    for cs in cfg.convs:
        key, sub = jax.random.split(key)
        fan_in = cs.cin * cs.r * cs.r
        w = jax.random.normal(sub, (cs.cout, cs.cin, cs.r, cs.r),
                              jnp.float32) * jnp.sqrt(2.0 / fan_in)
        params["conv"].append(w)
    if cfg.dense_out is not None:
        key, sub = jax.random.split(key)
        f = cfg.dense_in()
        w = jax.random.normal(sub, (cfg.dense_out, f),
                              jnp.float32) * jnp.sqrt(2.0 / f)
        params["dense"] = {"w": w, "b": jnp.zeros((cfg.dense_out,),
                                                  jnp.float32)}
    return params


def filter_magnitudes(params: dict, layer: int) -> jax.Array:
    """APRC predictor input: the summed elements of each filter of a conv
    layer — (M,) signed magnitudes (paper §III-B)."""
    return params["conv"][layer].sum(axis=(1, 2, 3))


# --------------------------------------------------------------------------
# SNN step / scan
# --------------------------------------------------------------------------

def network_step(params: dict, cfg: NetConfig, s_in: jax.Array,
                 vmems: tuple[jax.Array, ...], *, use_pallas: bool = True):
    """One SNN timestep through all layers.

    Returns (per-layer output spikes tuple, new vmems tuple). This is the
    function AOT-exported for the rust runtime; per-layer spikes are what
    the cycle-level simulator consumes as its workload trace.
    """
    spikes = []
    new_vmems = []
    s = s_in
    for li, w in enumerate(params["conv"]):
        if use_pallas:
            s, v = spiking_conv_step(s, w, vmems[li], vth=cfg.vth,
                                     pad=cfg.pad)
        else:
            s, v = kref.spiking_conv_step_ref(s, w, vmems[li], vth=cfg.vth,
                                              pad=cfg.pad)
        spikes.append(s)
        new_vmems.append(v)
    if cfg.dense_out is not None:
        d = params["dense"]
        flat = s.reshape(-1)
        li = len(params["conv"])
        if use_pallas:
            s, v = spiking_dense_step(flat, d["w"], d["b"], vmems[li],
                                      vth=cfg.vth)
        else:
            s, v = kref.spiking_dense_step_ref(flat, d["w"], d["b"],
                                               vmems[li], vth=cfg.vth)
        spikes.append(s)
        new_vmems.append(v)
    return tuple(spikes), tuple(new_vmems)


def init_vmems(cfg: NetConfig) -> tuple[jax.Array, ...]:
    return tuple(jnp.zeros(s, jnp.float32) for s in cfg.vmem_shapes())


def run_snn(params: dict, cfg: NetConfig, spike_train: jax.Array,
            *, use_pallas: bool = True):
    """Run T timesteps with lax.scan; returns per-layer spike *counts*
    (summed over time). spike_train: (T, C, H, W)."""

    def step(vmems, s_in):
        spikes, new_vmems = network_step(params, cfg, s_in, vmems,
                                         use_pallas=use_pallas)
        return new_vmems, spikes

    _, spikes_t = jax.lax.scan(step, init_vmems(cfg), spike_train)
    return tuple(s.sum(axis=0) for s in spikes_t)


# --------------------------------------------------------------------------
# Input encoding
# --------------------------------------------------------------------------

def encode_phased(img01: jax.Array, timesteps: int) -> jax.Array:
    """Deterministic phased rate coding: pixel p in [0,1] emits
    floor(p*(t+1)) - floor(p*t) spikes at step t, i.e. ~p*T evenly spaced
    spikes over T steps. Integer-friendly so the rust port in
    rust/src/snn matches bit-for-bit. Output (T, ...)."""
    t = jnp.arange(timesteps, dtype=jnp.float32)[
        (slice(None),) + (None,) * img01.ndim]
    p = img01[None]
    return jnp.floor(p * (t + 1.0)) - jnp.floor(p * t)


# --------------------------------------------------------------------------
# ANN twin (training-time only)
# --------------------------------------------------------------------------

def ann_forward(params: dict, cfg: NetConfig, x: jax.Array,
                *, collect: bool = False):
    """ReLU twin of the SNN used for training + threshold-balanced
    conversion. x: (B, C, H, W) in [0,1]. The final layer is linear
    (logits / mask scores). When ``collect``, also returns every
    post-ReLU hidden activation for conversion calibration."""
    acts = []
    nconv = len(params["conv"])
    for li, w in enumerate(params["conv"]):
        x = jax.vmap(lambda xi, wi=w: kref.conv2d_ref(xi, wi, cfg.pad))(x)
        last_conv_is_output = cfg.dense_out is None and li == nconv - 1
        if not last_conv_is_output:
            x = jax.nn.relu(x)
            acts.append(x)
    if cfg.dense_out is not None:
        d = params["dense"]
        x = x.reshape(x.shape[0], -1) @ d["w"].T + d["b"]
    return (x, acts) if collect else x
