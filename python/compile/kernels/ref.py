"""Pure-jnp oracle for the Pallas kernels — the CORE correctness signal.

Every kernel in this package must match these references under interpret
mode (f32 op order may differ, so membrane potentials use assert_allclose
with tight tolerances; spikes must match exactly away from the threshold).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def conv2d_ref(spikes: jax.Array, weights: jax.Array, pad: int) -> jax.Array:
    """(C,H,W) x (M,C,R,R) -> (M,E,E) convolution via lax.conv."""
    out = lax.conv_general_dilated(
        spikes[None],              # (1, C, H, W)
        weights,                   # (M, C, R, R) OIHW
        window_strides=(1, 1),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return out[0]


def lif_update(vmem: jax.Array, z: jax.Array, vth: float):
    """Eq. 1/3: integrate, fire with a unit step, reset by subtraction."""
    v = vmem + z
    spk = (v >= vth).astype(jnp.float32)
    return spk, v - vth * spk


def spiking_conv_step_ref(spikes, weights, vmem, *, vth: float, pad: int):
    """Oracle for kernels.spiking_conv.spiking_conv_step."""
    z = conv2d_ref(spikes, weights, pad)
    return lif_update(vmem, z, vth)


def spiking_dense_step_ref(spikes, weights, bias, vmem, *, vth: float):
    """Oracle for kernels.spiking_dense.spiking_dense_step."""
    z = weights @ spikes + bias
    return lif_update(vmem, z, vth)
