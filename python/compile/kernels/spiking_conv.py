"""L1 Pallas kernel: one SNN timestep of a spiking convolution layer.

The paper's hot spot is the event-driven spike-gated convolution plus the
LIF membrane update (Eq. 1-3). On the FPGA this is a spatial SPE array;
per DESIGN.md §3 we re-express it for the TPU programming model as a
*shift-and-matmul* convolution over the binary spike tensor fused with the
LIF threshold/reset:

* the R*R static shifts turn the conv into R*R dense (M_tile, C) x (C, E*E)
  matmuls — exactly the MXU-friendly formulation (spikes are {0,1} floats,
  so on real hardware these are bfloat16 matmuls on the systolic array);
* the grid tiles output channels; one tile's weights + membrane block stay
  resident in VMEM while the (padded) spike map is shared across grid
  steps — the BlockSpec below is the HBM<->VMEM schedule that the FPGA
  implemented with per-cluster weight banks.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO which both the python
tests and the rust runtime execute. Real-TPU performance is *estimated*
in DESIGN.md §8 from the VMEM footprint / MXU utilisation of this tiling.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def pick_block_m(m: int, target: int = 8) -> int:
    """Largest divisor of ``m`` that is <= ``target``.

    Output-channel tiles must divide M exactly so every grid step is full;
    8 keeps the weight tile + two (bm, E, E) blocks comfortably inside a
    TPU core's VMEM for every layer shape in the paper's two networks.
    """
    best = 1
    for d in range(1, min(m, target) + 1):
        if m % d == 0:
            best = d
    return best


def _conv_lif_kernel(sp_ref, w_ref, v_ref, os_ref, ov_ref, *,
                     block_m: int, c: int, r: int, eh: int, ew: int,
                     vth: float):
    """Kernel body for one output-channel tile.

    sp_ref: (C, Hp, Wp) padded binary spikes   (shared across grid steps)
    w_ref:  (block_m, C, R, R) weight tile     (resident per grid step)
    v_ref:  (block_m, Eh, Ew) membrane potentials
    os_ref/ov_ref: output spike / updated membrane blocks
    """
    s = sp_ref[...]
    w = w_ref[...]
    acc = jnp.zeros((block_m, eh * ew), jnp.float32)
    # R*R static shifts; each is a (bm, C) @ (C, Eh*Ew) matmul on the MXU.
    for j in range(r):
        for k in range(r):
            patch = s[:, j:j + eh, k:k + ew].reshape(c, eh * ew)
            acc = acc + jnp.dot(w[:, :, j, k], patch)
    v = v_ref[...] + acc.reshape(block_m, eh, ew)
    spk = (v >= vth).astype(jnp.float32)
    os_ref[...] = spk
    ov_ref[...] = v - vth * spk


@functools.partial(jax.jit, static_argnames=("vth", "pad", "block_m"))
def spiking_conv_step(spikes: jax.Array, weights: jax.Array,
                      vmem: jax.Array, *, vth: float, pad: int,
                      block_m: int | None = None):
    """One SNN timestep of a conv layer.

    Args:
      spikes:  (C, H, W) float32 binary input spike map.
      weights: (M, C, R, R) float32 filters.
      vmem:    (M, Eh, Ew) float32 membrane potentials,
               Eh = H + 2*pad - R + 1, Ew likewise.
      vth:     firing threshold (static).
      pad:     zero padding per side. ``pad == R - 1`` is the APRC *full*
               convolution (every filter tap sees every input element,
               Eq. 5); ``pad == R // 2`` is the baseline same-pad conv.

    Returns:
      (out_spikes (M, Eh, Ew), new_vmem (M, Eh, Ew)) — LIF with
      reset-by-subtraction per Eq. 1.
    """
    c, h, w_in = spikes.shape
    m, cw, r, r2 = weights.shape
    assert cw == c and r == r2, (weights.shape, spikes.shape)
    eh = h + 2 * pad - r + 1
    ew = w_in + 2 * pad - r + 1
    assert vmem.shape == (m, eh, ew), (vmem.shape, (m, eh, ew))
    if block_m is None:
        block_m = pick_block_m(m)
    assert m % block_m == 0

    sp = jnp.pad(spikes, ((0, 0), (pad, pad), (pad, pad)))
    hp, wp = h + 2 * pad, w_in + 2 * pad
    kernel = functools.partial(_conv_lif_kernel, block_m=block_m, c=c,
                               r=r, eh=eh, ew=ew, vth=vth)
    out_spikes, new_vmem = pl.pallas_call(
        kernel,
        grid=(m // block_m,),
        in_specs=[
            pl.BlockSpec((c, hp, wp), lambda i: (0, 0, 0)),
            pl.BlockSpec((block_m, c, r, r), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((block_m, eh, ew), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_m, eh, ew), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_m, eh, ew), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, eh, ew), jnp.float32),
            jax.ShapeDtypeStruct((m, eh, ew), jnp.float32),
        ],
        interpret=True,
    )(sp, weights, vmem)
    return out_spikes, new_vmem


def vmem_bytes_estimate(c: int, h: int, w: int, m: int, r: int, pad: int,
                        block_m: int | None = None) -> int:
    """Estimated TPU VMEM residency of one grid step (DESIGN.md §8):
    padded spike map + weight tile + 3x (bm, E, E) f32 blocks."""
    if block_m is None:
        block_m = pick_block_m(m)
    e = h + 2 * pad - r + 1
    hp, wp = h + 2 * pad, w + 2 * pad
    floats = c * hp * wp + block_m * c * r * r + 3 * block_m * e * e
    return 4 * floats
