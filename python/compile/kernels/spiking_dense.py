"""L1 Pallas kernel: one SNN timestep of a spiking fully-connected layer.

Used for the classifier's output layer (flattened conv spikes -> 10 output
neurons). Small enough for a single VMEM-resident block: the whole
(K, F) weight matrix and the F-element spike vector fit in one grid step,
so there is no BlockSpec tiling here — the matmul-vector product is the
MXU mapping and the LIF update is fused exactly as in spiking_conv.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dense_lif_kernel(s_ref, w_ref, b_ref, v_ref, os_ref, ov_ref, *,
                      vth: float):
    s = s_ref[...]
    z = jnp.dot(w_ref[...], s) + b_ref[...]
    v = v_ref[...] + z
    spk = (v >= vth).astype(jnp.float32)
    os_ref[...] = spk
    ov_ref[...] = v - vth * spk


@functools.partial(jax.jit, static_argnames=("vth",))
def spiking_dense_step(spikes: jax.Array, weights: jax.Array,
                       bias: jax.Array, vmem: jax.Array, *, vth: float):
    """One SNN timestep of a dense layer.

    Args:
      spikes:  (F,) float32 binary input spikes (flattened previous layer).
      weights: (K, F) float32.
      bias:    (K,) float32 constant input current per timestep (Eq. 2).
      vmem:    (K,) float32 membrane potentials.

    Returns: (out_spikes (K,), new_vmem (K,)).
    """
    k, f = weights.shape
    assert spikes.shape == (f,) and vmem.shape == (k,) and bias.shape == (k,)
    kernel = functools.partial(_dense_lif_kernel, vth=vth)
    out_spikes, new_vmem = pl.pallas_call(
        kernel,
        out_shape=[
            jax.ShapeDtypeStruct((k,), jnp.float32),
            jax.ShapeDtypeStruct((k,), jnp.float32),
        ],
        interpret=True,
    )(spikes, weights, bias, vmem)
    return out_spikes, new_vmem
