"""Deterministic synthetic datasets (no internet in the sandbox).

Substitutes for the paper's data per DESIGN.md §2:

* ``digits``      — MNIST substitute: 28x28 grayscale seven-segment-style
                    digit glyphs with integer affine jitter, per-segment
                    wobble, brightness variation and additive noise.
* ``road_scenes`` — MLND-Capstone driving-video substitute: 80x160x3
                    perspective road scenes with lane markings plus the
                    ground-truth binary road mask.

Everything is generated with *integer-only* math on top of a splitmix64
PRNG so the Rust port in ``rust/src/data/`` reproduces the streams
byte-for-byte (cross-checked by FNV-1a hashes stored in
``artifacts/meta.json``). splitmix64 is counter-based (the state advances
by a fixed gamma per draw), so Python vectorises blocks of draws with
numpy while Rust draws sequentially — the streams are identical.
"""

from __future__ import annotations

import numpy as np

MASK64 = np.uint64(0xFFFFFFFFFFFFFFFF)
GAMMA = 0x9E3779B97F4A7C15
MIX1 = 0xBF58476D1CE4E5B9
MIX2 = 0x94D049BB133111EB

DIGIT_H = 28
DIGIT_W = 28
ROAD_H = 80
ROAD_W = 160


def _mix_array(z: np.ndarray) -> np.ndarray:
    """Vectorised splitmix64 finaliser (u64 arrays wrap silently)."""
    z = (z ^ (z >> np.uint64(30))) * np.uint64(MIX1)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(MIX2)
    return z ^ (z >> np.uint64(31))


class SplitMix64:
    """splitmix64 PRNG — trivially portable to Rust (sequential there).

    State is kept as a Python int (masked to 64 bits) so scalar draws never
    trip numpy overflow warnings; block draws vectorise with numpy."""

    def __init__(self, seed: int):
        self.state = seed & 0xFFFFFFFFFFFFFFFF

    def next_u64(self) -> int:
        self.state = (self.state + GAMMA) & 0xFFFFFFFFFFFFFFFF
        z = self.state
        z = ((z ^ (z >> 30)) * MIX1) & 0xFFFFFFFFFFFFFFFF
        z = ((z ^ (z >> 27)) * MIX2) & 0xFFFFFFFFFFFFFFFF
        return z ^ (z >> 31)

    def next_block(self, n: int) -> np.ndarray:
        """n consecutive draws as a u64 array; advances the state by n.
        Identical to calling next_u64() n times."""
        idx = np.arange(1, n + 1, dtype=np.uint64)
        states = np.uint64(self.state) + idx * np.uint64(GAMMA)
        self.state = (self.state + n * GAMMA) & 0xFFFFFFFFFFFFFFFF
        return _mix_array(states)

    def next_below(self, n: int) -> int:
        """Uniform integer in [0, n). Modulo bias is irrelevant here and
        keeps the Rust port a one-liner."""
        return self.next_u64() % n

    def next_range(self, lo: int, hi: int) -> int:
        """Uniform integer in [lo, hi] inclusive."""
        return lo + self.next_below(hi - lo + 1)


# --------------------------------------------------------------------------
# Digits
# --------------------------------------------------------------------------

# Seven-segment layout inside the 28x28 box (inclusive coordinates).
#      A
#    F   B
#      G
#    E   C
#      D
# Segments as (y0, x0, y1, x1) line endpoints on the glyph grid.
_SEG_COORDS = {
    "A": (4, 9, 4, 19),
    "B": (4, 19, 13, 19),
    "C": (13, 19, 23, 19),
    "D": (23, 9, 23, 19),
    "E": (13, 9, 23, 9),
    "F": (4, 9, 13, 9),
    "G": (13, 9, 13, 19),
}

_DIGIT_SEGMENTS = {
    0: "ABCDEF",
    1: "BC",
    2: "ABGED",
    3: "ABGCD",
    4: "FGBC",
    5: "AFGCD",
    6: "AFGECD",
    7: "ABC",
    8: "ABCDEFG",
    9: "ABCDFG",
}


def _draw_thick_line(img: np.ndarray, y0: int, x0: int, y1: int, x1: int,
                     thickness: int, value: int) -> None:
    """Axis-aligned line with thickness (all templates are axis-aligned,
    which keeps the Rust port trivial while staying exact)."""
    h, w = img.shape
    t0 = -(thickness // 2)
    t1 = thickness // 2 + (thickness & 1)
    if y0 == y1:  # horizontal
        for x in range(min(x0, x1), max(x0, x1) + 1):
            for dy in range(t0, t1):
                y = y0 + dy
                if 0 <= y < h and 0 <= x < w:
                    img[y, x] = max(img[y, x], value)
    else:  # vertical
        for y in range(min(y0, y1), max(y0, y1) + 1):
            for dx in range(t0, t1):
                x = x0 + dx
                if 0 <= y < h and 0 <= x < w:
                    img[y, x] = max(img[y, x], value)


def gen_digit(rng: SplitMix64, label: int) -> np.ndarray:
    """Render one 28x28 uint8 digit glyph. Consumes a fixed-structure PRNG
    stream: 4 header draws + 2 wobble draws per segment + 784 noise draws."""
    img = np.zeros((DIGIT_H, DIGIT_W), dtype=np.int64)
    dy = rng.next_range(-2, 2)
    dx = rng.next_range(-3, 3)
    thickness = rng.next_range(2, 3)
    brightness = rng.next_range(170, 255)
    for seg in _DIGIT_SEGMENTS[label]:
        y0, x0, y1, x1 = _SEG_COORDS[seg]
        wy = rng.next_range(-1, 1)
        wx = rng.next_range(-1, 1)
        _draw_thick_line(img, y0 + dy + wy, x0 + dx + wx,
                         y1 + dy + wy, x1 + dx + wx, thickness, brightness)
    noise = (rng.next_block(DIGIT_H * DIGIT_W) % np.uint64(36)) \
        .astype(np.int64).reshape(DIGIT_H, DIGIT_W)
    img = np.minimum(255, img + noise)
    return img.astype(np.uint8)


def gen_digits(seed: int, count: int) -> tuple[np.ndarray, np.ndarray]:
    """Generate ``count`` digit images with PRNG-chosen labels.

    Returns (images [count,28,28] u8, labels [count] u8)."""
    rng = SplitMix64(seed)
    imgs = np.zeros((count, DIGIT_H, DIGIT_W), dtype=np.uint8)
    labels = np.zeros((count,), dtype=np.uint8)
    for i in range(count):
        label = rng.next_below(10)
        labels[i] = label
        imgs[i] = gen_digit(rng, label)
    return imgs, labels


# --------------------------------------------------------------------------
# Road scenes
# --------------------------------------------------------------------------

def gen_road_scene(rng: SplitMix64) -> tuple[np.ndarray, np.ndarray]:
    """One 80x160 RGB road scene + binary road mask.

    Stream structure: 10 header draws, then exactly one draw per pixel in
    (y, x) order. Returns (img [80,160,3] u8, mask [80,160] u8 in {0,1})."""
    h, w = ROAD_H, ROAD_W
    img = np.zeros((h, w, 3), dtype=np.int64)
    mask = np.zeros((h, w), dtype=np.uint8)

    horizon = rng.next_range(20, 30)
    vx = rng.next_range(60, 100)            # vanishing point x
    bl = rng.next_range(10, 40)             # road left edge at bottom
    br = rng.next_range(120, 150)           # road right edge at bottom
    sky_r = rng.next_range(90, 140)
    sky_g = rng.next_range(130, 180)
    sky_b = rng.next_range(190, 240)
    grass_g = rng.next_range(100, 150)
    road_gray = rng.next_range(90, 130)
    dash_phase = rng.next_below(12)

    raw = rng.next_block(h * w).reshape(h, w)
    denom = (h - 1) - horizon  # >= 49
    for y in range(h):
        if y < horizon:
            # Sky gradient: darker towards the top.
            fade = (horizon - y) * 40 // horizon
            n = (raw[y] % np.uint64(8)).astype(np.int64)
            img[y, :, 0] = sky_r - fade + n
            img[y, :, 1] = sky_g - fade + n
            img[y, :, 2] = sky_b - fade // 2 + n
        else:
            t = y - horizon
            le = vx + (bl - vx) * t // denom
            re = vx + (br - vx) * t // denom
            cx = vx + ((bl + br) // 2 - vx) * t // denom
            lane_w = 1 + t * 3 // denom
            dash_on = ((y + dash_phase) // 6) % 2 == 0
            n = (raw[y] % np.uint64(16)).astype(np.int64)
            x = np.arange(w)
            on_road = (x >= le) & (x <= re)
            mask[y, on_road] = 1
            v = np.where(on_road, road_gray + n, 0)
            if dash_on:
                v = np.where(on_road & (np.abs(x - cx) <= lane_w), 220 + n, v)
            v = np.where(on_road & ((x == le) | (x == re)), 200 + n, v)
            img[y, :, 0] = np.where(on_road, v, 60 + n)
            img[y, :, 1] = np.where(on_road, v, grass_g + n)
            img[y, :, 2] = np.where(on_road, v, 40 + n)
    np.clip(img, 0, 255, out=img)
    return img.astype(np.uint8), mask


def gen_road_scenes(seed: int, count: int) -> tuple[np.ndarray, np.ndarray]:
    """Returns (imgs [count,80,160,3] u8, masks [count,80,160] u8)."""
    rng = SplitMix64(seed)
    imgs = np.zeros((count, ROAD_H, ROAD_W, 3), dtype=np.uint8)
    masks = np.zeros((count, ROAD_H, ROAD_W), dtype=np.uint8)
    for i in range(count):
        imgs[i], masks[i] = gen_road_scene(rng)
    return imgs, masks


# --------------------------------------------------------------------------
# Hashing for the cross-language determinism check
# --------------------------------------------------------------------------

def fnv1a64(data: bytes) -> int:
    """FNV-1a 64-bit — the same tiny hash lives in rust/src/data."""
    h = 0xCBF29CE484222325
    for b in data:
        h = ((h ^ b) * 0x100000001B3) & 0xFFFFFFFFFFFFFFFF
    return h


def digits_hash(seed: int, count: int) -> int:
    imgs, labels = gen_digits(seed, count)
    return fnv1a64(imgs.tobytes() + labels.tobytes())


def road_scenes_hash(seed: int, count: int) -> int:
    imgs, masks = gen_road_scenes(seed, count)
    return fnv1a64(imgs.tobytes() + masks.tobytes())
