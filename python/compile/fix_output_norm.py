"""One-off migration: apply output-layer normalisation to artifacts that
were converted before `convert_to_snn` normalised the output layer.

The stored SNN weights are an invertible transform of the ANN weights
given the recorded lambdas, and `ann_forward` run *with the SNN params*
yields logits in the original trained units (hidden rates = a/lambda are
exactly compensated by the rescaled weights). So we can compute
lambda_out on calibration data and rescale the output layer in place —
no retraining.

Usage: python -m compile.fix_output_norm --out ../artifacts [names...]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

import jax.numpy as jnp

from . import aot, datasets, model, train


def fix_variant(out_dir: Path, name: str, pct: float = 99.9) -> None:
    loaded = aot.load_weights(out_dir, name)
    if loaded is None:
        print(f"[{name}] no cached weights; skipping")
        return
    params, meta = loaded
    if len(meta["lambdas"]) > len(meta["feature_sizes"]) - (
            0 if meta["dense_out"] is not None else 1):
        print(f"[{name}] already normalised; skipping")
        return
    cfg = model.config_by_name(name)

    if cfg.dense_out is not None:
        imgs, _ = datasets.gen_digits(train.DIGITS_TRAIN_SEED, 512)
        calib = jnp.asarray(imgs, jnp.float32)[:, None] / 255.0
    else:
        imgs, _ = datasets.gen_road_scenes(train.ROADS_TRAIN_SEED, 16)
        calib = jnp.asarray(imgs, jnp.float32).transpose(0, 3, 1, 2) / 255.0

    # SNN params act as an ANN whose logits are in original units.
    logits = model.ann_forward(params, cfg, calib)
    lam_out = max(float(jnp.percentile(jnp.abs(logits), pct)), 1e-6)
    print(f"[{name}] lambda_out = {lam_out:.4f}")
    if cfg.dense_out is not None:
        params["dense"]["w"] = params["dense"]["w"] / lam_out
        params["dense"]["b"] = params["dense"]["b"] / lam_out
        acc = train.eval_snn_classifier(params, cfg, 512)
        print(f"[{name}] SNN accuracy after fix: {acc:.4f}")
        extra = {"ann_metric": meta.get("ann_metric"), "snn_metric": acc}
    else:
        params["conv"][-1] = params["conv"][-1] / lam_out
        thr, iou = train.calibrate_seg_threshold(params, cfg, 8)
        print(f"[{name}] SNN IoU after fix: {iou:.4f} @ rate>={thr}")
        extra = {"snn_metric": iou, "seg_rate_threshold": thr}

    lambdas = list(meta["lambdas"]) + [lam_out]
    train.save_weights(out_dir, cfg, params, lambdas, extra)
    hlo = out_dir / f"{cfg.name}.step.hlo.txt"
    aot.export_step_hlo(cfg, params, hlo)
    print(f"[{name}] weights + HLO re-exported")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("names", nargs="*",
                    default=["classifier_aprc", "classifier_plain",
                             "segmenter_aprc", "segmenter_plain"])
    args = ap.parse_args()
    out_dir = Path(args.out).resolve()
    for name in args.names:
        fix_variant(out_dir, name)
    # Refresh variant metrics inside meta.json if it exists.
    meta_path = out_dir / "meta.json"
    if meta_path.exists():
        meta = json.loads(meta_path.read_text())
        for v in meta.get("variants", []):
            wj = out_dir / f"{v['name']}.weights.json"
            if wj.exists():
                w = json.loads(wj.read_text())
                for k in ("ann_metric", "snn_metric",
                          "seg_rate_threshold"):
                    if k in w and w[k] is not None:
                        v[k] = w[k]
        meta_path.write_text(json.dumps(meta, indent=1))


if __name__ == "__main__":
    main()
