"""Build-output contract tests: the artifacts the rust side depends on.
These run against the artifacts/ directory produced by `make artifacts`
(they are the python half of the cross-language contract)."""

import json
from pathlib import Path

import numpy as np
import pytest

from compile import aot, datasets, model

ARTIFACTS = Path(__file__).resolve().parents[2] / "artifacts"

pytestmark = pytest.mark.skipif(
    not (ARTIFACTS / "meta.json").exists(),
    reason="run `make artifacts` first")

VARIANTS = ["classifier_aprc", "classifier_plain", "segmenter_aprc",
            "segmenter_plain"]


def test_meta_lists_all_variants():
    meta = json.loads((ARTIFACTS / "meta.json").read_text())
    names = {v["name"] for v in meta["variants"]}
    assert names == set(VARIANTS)


@pytest.mark.parametrize("name", VARIANTS)
def test_weights_roundtrip(name):
    loaded = aot.load_weights(ARTIFACTS, name)
    assert loaded is not None, f"{name} missing"
    params, meta = loaded
    cfg = model.config_by_name(name)
    assert len(params["conv"]) == len(cfg.convs)
    for w, spec in zip(params["conv"], cfg.convs):
        assert w.shape == (spec.cout, spec.cin, spec.r, spec.r)
    if cfg.dense_out is not None:
        assert params["dense"]["w"].shape == (cfg.dense_out,
                                              cfg.dense_in())
    blob = (ARTIFACTS / f"{name}.weights.bin").read_bytes()
    assert f"{datasets.fnv1a64(blob):016x}" == meta["blob_fnv1a64"]


@pytest.mark.parametrize("name", VARIANTS)
def test_hlo_exports_exist_and_have_no_elided_constants(name):
    text = (ARTIFACTS / f"{name}.step.hlo.txt").read_text()
    assert "ENTRY" in text
    # Elided big constants would silently corrupt the rust runtime.
    assert "constant({...})" not in text, \
        "HLO text contains elided constants — weights must be parameters"


def test_reported_metrics_meet_paper_claims():
    meta = json.loads((ARTIFACTS / "meta.json").read_text())
    by_name = {v["name"]: v for v in meta["variants"]}
    # Paper claims 98.5% on MNIST; our synthetic split must match it.
    clf = json.loads(
        (ARTIFACTS / "classifier_aprc.weights.json").read_text())
    assert clf["snn_metric"] >= 0.985
    seg = json.loads(
        (ARTIFACTS / "segmenter_aprc.weights.json").read_text())
    assert seg["snn_metric"] >= 0.9  # IoU
    assert by_name["classifier_aprc"]["timesteps"] == 24


def test_encoding_crosscheck_reproducible():
    meta = json.loads((ARTIFACTS / "meta.json").read_text())
    again = aot.encoding_crosscheck()
    assert again == meta["encoding_crosscheck"]
