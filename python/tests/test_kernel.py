"""L1 correctness: Pallas kernels vs the pure-jnp oracle — the CORE
correctness signal. Spikes must match exactly; membrane potentials to f32
tolerance. Hypothesis sweeps shapes/rates/paddings."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref as kref
from compile.kernels.spiking_conv import (pick_block_m, spiking_conv_step,
                                          vmem_bytes_estimate)
from compile.kernels.spiking_dense import spiking_dense_step


def rand_case(key, c, h, w, m, r, pad, rate, vscale=0.3):
    k1, k2, k3 = jax.random.split(key, 3)
    spikes = (jax.random.uniform(k1, (c, h, w)) < rate).astype(jnp.float32)
    weights = jax.random.normal(k2, (m, c, r, r), jnp.float32) * 0.3
    eh = h + 2 * pad - r + 1
    ew = w + 2 * pad - r + 1
    vmem = jax.random.normal(k3, (m, eh, ew), jnp.float32) * vscale
    return spikes, weights, vmem


@pytest.mark.parametrize("pad", [1, 2])
@pytest.mark.parametrize("shape", [(1, 28, 28, 16), (3, 10, 20, 8),
                                   (16, 9, 9, 32), (5, 12, 14, 6)])
def test_conv_matches_ref(pad, shape):
    c, h, w, m = shape
    spikes, weights, vmem = rand_case(jax.random.PRNGKey(42), c, h, w, m,
                                      3, pad, 0.2)
    os_k, ov_k = spiking_conv_step(spikes, weights, vmem, vth=1.0, pad=pad)
    os_r, ov_r = kref.spiking_conv_step_ref(spikes, weights, vmem,
                                            vth=1.0, pad=pad)
    assert bool((os_k == os_r).all()), "spike mismatch"
    np.testing.assert_allclose(ov_k, ov_r, atol=1e-5)


@hypothesis.settings(max_examples=25, deadline=None)
@hypothesis.given(
    c=st.integers(1, 8),
    h=st.integers(4, 16),
    w=st.integers(4, 16),
    m=st.integers(1, 12),
    pad=st.sampled_from([1, 2]),
    rate=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv_matches_ref_hypothesis(c, h, w, m, pad, rate, seed):
    spikes, weights, vmem = rand_case(jax.random.PRNGKey(seed), c, h, w,
                                      m, 3, pad, rate)
    os_k, ov_k = spiking_conv_step(spikes, weights, vmem, vth=1.0, pad=pad)
    os_r, ov_r = kref.spiking_conv_step_ref(spikes, weights, vmem,
                                            vth=1.0, pad=pad)
    assert bool((os_k == os_r).all())
    np.testing.assert_allclose(ov_k, ov_r, atol=1e-5)


@hypothesis.settings(max_examples=20, deadline=None)
@hypothesis.given(
    f=st.integers(1, 200),
    k=st.integers(1, 16),
    rate=st.floats(0.0, 1.0),
    vth=st.sampled_from([0.5, 1.0, 2.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_dense_matches_ref_hypothesis(f, k, rate, vth, seed):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    spikes = (jax.random.uniform(k1, (f,)) < rate).astype(jnp.float32)
    w = jax.random.normal(k2, (k, f), jnp.float32) * 0.3
    b = jax.random.normal(k3, (k,), jnp.float32) * 0.05
    vmem = jax.random.normal(k4, (k,), jnp.float32) * 0.2
    os_k, ov_k = spiking_dense_step(spikes, w, b, vmem, vth=vth)
    os_r, ov_r = kref.spiking_dense_step_ref(spikes, w, b, vmem, vth=vth)
    assert bool((os_k == os_r).all())
    np.testing.assert_allclose(ov_k, ov_r, atol=1e-5)


def test_reset_by_subtraction():
    # A neuron driven at 0.6/step with vth=1 fires on steps 2,4,5,7...
    # (accumulated 0.6,1.2->0.2,0.8,1.4->0.4,1.0->0.0,...).
    spikes = jnp.ones((1, 1, 1), jnp.float32)
    w = jnp.full((1, 1, 1, 1), 0.6, jnp.float32)
    vmem = jnp.zeros((1, 1, 1), jnp.float32)
    fired = []
    for _ in range(5):
        out, vmem = spiking_conv_step(spikes, w, vmem, vth=1.0, pad=0)
        fired.append(int(out.sum()))
    assert fired == [0, 1, 0, 1, 1]


def test_zero_input_only_bias_acts():
    f, k = 10, 4
    spikes = jnp.zeros((f,), jnp.float32)
    w = jnp.ones((k, f), jnp.float32)
    b = jnp.array([0.0, 0.5, 1.0, 2.0], jnp.float32)
    vmem = jnp.zeros((k,), jnp.float32)
    out, v = spiking_dense_step(spikes, w, b, vmem, vth=1.0)
    assert out.tolist() == [0.0, 0.0, 1.0, 1.0]
    np.testing.assert_allclose(v, [0.0, 0.5, 0.0, 1.0], atol=1e-6)


def test_pick_block_m_divides():
    for m in range(1, 65):
        bm = pick_block_m(m)
        assert m % bm == 0 and bm <= 8


def test_vmem_estimate_within_tpu_budget():
    # Every layer of both networks must fit a 16 MiB VMEM tile budget.
    for (c, h, w, m, pad) in [(1, 28, 28, 16, 2), (16, 30, 30, 32, 2),
                              (32, 32, 32, 8, 2), (3, 80, 160, 8, 2),
                              (32, 86, 166, 32, 2), (16, 90, 170, 1, 2)]:
        est = vmem_bytes_estimate(c, h, w, m, 3, pad)
        assert est < 16 * 2**20, f"{(c, h, w, m)}: {est} bytes"


def test_full_conv_eq5_proportionality():
    """Eq. 5: with full padding, the summed dV of output channel m is
    exactly sum_c (per-input-channel filter magnitude) x (per-channel
    spike count) — and when all input channels fire equally, exactly
    filter_magnitude x spike count."""
    key = jax.random.PRNGKey(7)
    spikes, weights, _ = rand_case(key, 4, 8, 8, 6, 3, 2, 0.3, vscale=0.0)
    vmem = jnp.zeros((6, 10, 10), jnp.float32)  # E = 8 + 2*2 - 3 + 1
    _, v = spiking_conv_step(spikes, weights, vmem, vth=1e9, pad=2)
    per_channel_mags = weights.sum(axis=(2, 3))        # (M, C)
    nnz_c = spikes.sum(axis=(1, 2))                    # (C,)
    expect = per_channel_mags @ nnz_c
    np.testing.assert_allclose(v.sum(axis=(1, 2)), expect, rtol=1e-4)

    # Uniform per-channel firing -> the paper's headline form.
    uniform = jnp.ones((4, 8, 8), jnp.float32)
    vmem0 = jnp.zeros((6, 10, 10), jnp.float32)
    _, v2 = spiking_conv_step(uniform, weights, vmem0, vth=1e9, pad=2)
    mags = weights.sum(axis=(1, 2, 3))
    np.testing.assert_allclose(v2.sum(axis=(1, 2)), mags * 64.0,
                               rtol=1e-4)


def test_same_pad_breaks_eq5():
    key = jax.random.PRNGKey(8)
    spikes = jnp.zeros((1, 8, 8), jnp.float32).at[0, 0, 0].set(1.0)
    weights = jnp.ones((1, 1, 3, 3), jnp.float32)
    vmem = jnp.zeros((1, 8, 8), jnp.float32)
    _, v = spiking_conv_step(spikes, weights, vmem, vth=1e9, pad=1)
    # Corner spike: only 4 of 9 taps land.
    assert float(v.sum()) == pytest.approx(4.0)
