"""L2 correctness: network step/scan consistency, geometry, encoding,
and the APRC (Eq. 5) property at network level."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


@pytest.fixture(scope="module")
def clf_cfg():
    return model.classifier_config(aprc=True, timesteps=6)


@pytest.fixture(scope="module")
def clf_params(clf_cfg):
    return model.init_params(clf_cfg, jax.random.PRNGKey(0))


def test_classifier_geometry():
    cfg = model.classifier_config(aprc=True)
    assert cfg.feature_sizes() == [(16, 30, 30), (32, 32, 32), (8, 34, 34)]
    assert cfg.dense_in() == 8 * 34 * 34
    cfg_p = model.classifier_config(aprc=False)
    assert cfg_p.feature_sizes() == [(16, 28, 28), (32, 28, 28),
                                     (8, 28, 28)]


def test_segmenter_geometry():
    cfg = model.segmenter_config(aprc=True)
    sizes = cfg.feature_sizes()
    assert sizes[0] == (8, 82, 162)
    assert sizes[-1] == (1, 92, 172)
    assert cfg.dense_out is None
    assert cfg.num_layers() == 6


def test_step_pallas_equals_ref(clf_cfg, clf_params):
    x = jax.random.uniform(jax.random.PRNGKey(1), (1, 28, 28))
    s_in = model.encode_phased(x, clf_cfg.timesteps)[0]
    vmems = model.init_vmems(clf_cfg)
    sp, vp = model.network_step(clf_params, clf_cfg, s_in, vmems,
                                use_pallas=True)
    sr, vr = model.network_step(clf_params, clf_cfg, s_in, vmems,
                                use_pallas=False)
    for a, b in zip(sp, sr):
        assert bool((a == b).all())
    for a, b in zip(vp, vr):
        np.testing.assert_allclose(a, b, atol=1e-5)


def test_scan_accumulates_steps(clf_cfg, clf_params):
    """run_snn's scan must equal manually chaining network_step."""
    x = jax.random.uniform(jax.random.PRNGKey(2), (1, 28, 28))
    train = model.encode_phased(x, clf_cfg.timesteps)
    counts = model.run_snn(clf_params, clf_cfg, train, use_pallas=False)

    vmems = model.init_vmems(clf_cfg)
    manual = [jnp.zeros(s) for s in clf_cfg.vmem_shapes()]
    totals = [jnp.zeros(s) for s in clf_cfg.vmem_shapes()]
    for t in range(clf_cfg.timesteps):
        spikes, vmems = model.network_step(clf_params, clf_cfg, train[t],
                                           vmems, use_pallas=False)
        totals = [tot + s for tot, s in zip(totals, spikes)]
    for a, b in zip(counts, totals):
        np.testing.assert_allclose(a, b, atol=1e-5)


def test_encode_phased_rate():
    img = jnp.array([[0.0, 0.25], [0.5, 1.0]])[None]
    train = model.encode_phased(img, 8)
    counts = train.sum(axis=0)[0]
    np.testing.assert_allclose(counts, [[0, 2], [4, 8]])
    # Binary.
    assert bool(jnp.isin(train, jnp.array([0.0, 1.0])).all())


def test_filter_magnitudes(clf_params):
    mags = model.filter_magnitudes(clf_params, 0)
    assert mags.shape == (16,)
    expect = clf_params["conv"][0].sum(axis=(1, 2, 3))
    np.testing.assert_allclose(mags, expect)


def test_ann_forward_shapes(clf_cfg, clf_params):
    x = jax.random.uniform(jax.random.PRNGKey(3), (2, 1, 28, 28))
    logits, acts = model.ann_forward(clf_params, clf_cfg, x, collect=True)
    assert logits.shape == (2, 10)
    assert len(acts) == 3
    assert all(bool((a >= 0).all()) for a in acts), "post-ReLU"


def test_network_eq5_property(clf_cfg, clf_params):
    """First layer of the APRC net: summed dV per output channel equals
    magnitude x input spike count (before any reset)."""
    x = jax.random.uniform(jax.random.PRNGKey(4), (1, 28, 28))
    s_in = model.encode_phased(x, 4)[1]
    from compile.kernels.spiking_conv import spiking_conv_step
    vmem = jnp.zeros((16, 30, 30), jnp.float32)
    _, v = spiking_conv_step(s_in, clf_params["conv"][0], vmem,
                             vth=1e9, pad=clf_cfg.pad)
    mags = model.filter_magnitudes(clf_params, 0)
    np.testing.assert_allclose(v.sum(axis=(1, 2)), mags * s_in.sum(),
                               rtol=1e-4)


def test_config_by_name_roundtrip():
    for name in ["classifier_aprc", "classifier_plain", "segmenter_aprc",
                 "segmenter_plain"]:
        cfg = model.config_by_name(name)
        assert cfg.name == name
    cfg = model.config_by_name("classifier_aprc", timesteps=7)
    assert cfg.timesteps == 7
