"""Synthetic dataset generators: determinism, stream structure, and the
hashes the rust side cross-checks."""

import numpy as np
import pytest

from compile import datasets as D


def test_splitmix_block_equals_sequential():
    r1, r2 = D.SplitMix64(123), D.SplitMix64(123)
    seq = [r1.next_u64() for _ in range(257)]
    blk = r2.next_block(257)
    assert [int(x) for x in blk] == seq
    # State advanced identically.
    assert r1.next_u64() == r2.next_u64()


def test_digits_deterministic():
    a, la = D.gen_digits(5, 8)
    b, lb = D.gen_digits(5, 8)
    assert np.array_equal(a, b) and np.array_equal(la, lb)
    c, _ = D.gen_digits(6, 8)
    assert not np.array_equal(a, c)


def test_digits_all_classes_renderable():
    rng = D.SplitMix64(1)
    for label in range(10):
        img = D.gen_digit(rng, label)
        bright = (img > 100).sum()
        assert 20 < bright < 500, f"digit {label}: {bright} bright px"


def test_digit_classes_distinct():
    """Noise-free-ish check: different digits differ in many pixels."""
    imgs = {}
    for label in range(10):
        rng = D.SplitMix64(42)  # same jitter stream per label
        imgs[label] = D.gen_digit(rng, label).astype(np.int32)
    for a in range(10):
        for b in range(a + 1, 10):
            diff = (np.abs(imgs[a] - imgs[b]) > 60).sum()
            assert diff > 10, f"digits {a} and {b} too similar"


def test_roads_deterministic_and_masked():
    imgs, masks = D.gen_road_scenes(9, 3)
    imgs2, masks2 = D.gen_road_scenes(9, 3)
    assert np.array_equal(imgs, imgs2) and np.array_equal(masks, masks2)
    assert set(np.unique(masks)) <= {0, 1}
    frac = masks.mean()
    assert 0.05 < frac < 0.6


def test_road_mask_monotone_width():
    rng = D.SplitMix64(33)
    _, mask = D.gen_road_scene(rng)
    widths = mask.sum(axis=1)
    assert widths[0] == 0  # sky
    assert widths[-1] > widths[45] > 0


def test_fnv_vector():
    assert D.fnv1a64(b"") == 0xCBF29CE484222325
    assert D.fnv1a64(b"a") == 0xAF63DC4C8601EC8C


def test_hash_apis():
    h1 = D.digits_hash(1, 4)
    h2 = D.digits_hash(1, 4)
    assert h1 == h2
    assert D.digits_hash(2, 4) != h1
    assert D.road_scenes_hash(1, 1) != D.road_scenes_hash(2, 1)
