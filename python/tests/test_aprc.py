"""APRC properties at the python level: the Fig. 4(c) worked example and
the proportionality/conversion machinery in train.py."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model, train
from compile.kernels.spiking_conv import spiking_conv_step


def test_fig4c_worked_example():
    """Two 3x3 filters, magnitudes 2.7 / 0.9, 8x8 input with 6 spikes:
    summed dV must be 16.2 / 5.4 (paper Fig. 4c)."""
    w = jnp.stack([
        jnp.full((1, 3, 3), 2.7 / 9.0),
        jnp.full((1, 3, 3), 0.9 / 9.0),
    ]).astype(jnp.float32)
    spikes = jnp.zeros((1, 8, 8)).at[0, [1, 2, 3, 4, 5, 6],
                                     [1, 2, 3, 4, 5, 6]].set(1.0)
    vmem = jnp.zeros((2, 10, 10), jnp.float32)
    _, v = spiking_conv_step(spikes, w, vmem, vth=1e9, pad=2)
    sums = v.sum(axis=(1, 2))
    np.testing.assert_allclose(sums, [16.2, 5.4], rtol=1e-5)
    assert sums[0] / sums[1] == pytest.approx(3.0, rel=1e-5)


def test_adam_decreases_quadratic():
    params = {"x": jnp.array([5.0, -3.0])}
    opt = train.adam_init(params)
    loss = lambda p: (p["x"] ** 2).sum()
    for _ in range(300):
        grads = jax.grad(loss)(params)
        params, opt = train.adam_update(params, grads, opt, lr=0.05)
    assert float(loss(params)) < 1e-2


def test_convert_preserves_argmax():
    """Output-layer normalisation is a uniform positive scale, so ANN
    argmax must be preserved by the converted logit weights."""
    cfg = model.classifier_config(aprc=False, timesteps=8)
    params = model.init_params(cfg, jax.random.PRNGKey(3))
    x = jax.random.uniform(jax.random.PRNGKey(4), (8, 1, 28, 28))
    snn, lambdas = train.convert_to_snn(params, cfg, x)
    assert len(lambdas) == 4  # 3 hidden + lambda_out
    logits_ann = model.ann_forward(params, cfg, x)
    logits_snn = model.ann_forward(snn, cfg, x)
    # snn logits are ANN logits / lambda_out (hidden scales cancel in the
    # linear view only approximately due to ReLU; check argmax agreement
    # on clearly-separated rows).
    margins = jnp.sort(logits_ann, axis=1)
    clear = (margins[:, -1] - margins[:, -2]) > 0.1
    a = jnp.argmax(logits_ann, axis=1)[clear]
    s = jnp.argmax(logits_snn, axis=1)[clear]
    assert bool((a == s).all())


def test_convert_hidden_rates_bounded():
    """After conversion, hidden activations on calibration data sit in
    [0, ~1] spike-rate units."""
    cfg = model.classifier_config(aprc=True, timesteps=8)
    params = model.init_params(cfg, jax.random.PRNGKey(5))
    x = jax.random.uniform(jax.random.PRNGKey(6), (16, 1, 28, 28))
    snn, _ = train.convert_to_snn(params, cfg, x)
    _, acts = model.ann_forward(snn, cfg, x, collect=True)
    for a in acts:
        assert float(jnp.percentile(a, 99.9)) <= 1.05


def test_crop_to_input_identity_when_same():
    cfg = model.segmenter_config(aprc=False)
    scores = jnp.ones((2, 80, 160))
    out = train._crop_to_input(cfg, scores)
    assert out.shape == (2, 80, 160)


def test_crop_to_input_center_when_full():
    cfg = model.segmenter_config(aprc=True)
    scores = jnp.arange(92 * 172, dtype=jnp.float32).reshape(1, 92, 172)
    out = train._crop_to_input(cfg, scores)
    assert out.shape == (1, 80, 160)
    assert float(out[0, 0, 0]) == float(scores[0, 6, 6])
