#!/usr/bin/env python3
"""Bench-regression gate: compare a fresh BENCH_*.json against the
committed baseline snapshot and fail CI on a regression.

Rules (per row, matched by ``name``):

* ``mean_ns`` may not regress by more than ``--max-regress`` (default
  0.25 = +25%) over the baseline.
* On *alloc-free* rows (baseline ``allocs_per_iter`` < 1.0), any real
  increase (>= +0.5 allocs/iter, tolerance for counter jitter) fails —
  these rows are the allocation-free hot-path invariants tracked in
  PERF.md.
* Rows present only in the fresh file are reported as untracked and do
  NOT fail the gate (that is how new benches bootstrap); refresh the
  baseline with ``--update`` to start tracking them.
* Rows present only in the baseline warn (a bench binary may not have
  run) but do not fail.
* Rows whose ``quick`` flags differ are compared anyway but flagged —
  --quick numbers are only comparable to --quick baselines.

``--update`` merges the fresh rows into the baseline file (by name)
instead of comparing — the documented baseline-refresh workflow.

``--self-test`` runs the gate against doctored in-memory documents and
exits non-zero if any rule misfires: this is the unit test CI runs
before trusting the gate.
"""

import argparse
import json
import os
import sys

SCHEMA = "skydiver-bench-v1"
ALLOC_FREE_BASE = 1.0   # baseline rows below this are "alloc-free"
ALLOC_JITTER = 0.5      # counted-allocator noise tolerance


def load(path):
    with open(path) as f:
        doc = json.load(f)
    if doc.get("schema") != SCHEMA:
        raise SystemExit(f"{path}: schema {doc.get('schema')!r} != "
                         f"{SCHEMA!r}")
    rows = doc.get("results")
    if not isinstance(rows, list):
        raise SystemExit(f"{path}: 'results' must be a list")
    return doc


def by_name(doc):
    return {r["name"]: r for r in doc.get("results", [])}


def compare(baseline, fresh, max_regress):
    """Return (failures, notes) comparing two parsed documents."""
    failures, notes = [], []
    base = by_name(baseline)
    new = by_name(fresh)
    if not base:
        notes.append("baseline is empty (bootstrap pending) — run "
                     "tools/bench_gate.py --update to start tracking")
    for name, row in new.items():
        b = base.get(name)
        if b is None:
            notes.append(f"untracked row {name!r} (not in baseline; "
                         f"--update to track)")
            continue
        if bool(b.get("quick")) != bool(row.get("quick")):
            notes.append(f"{name}: quick flag differs from baseline "
                         f"(baseline quick={b.get('quick')}, fresh "
                         f"quick={row.get('quick')}) — comparison is "
                         f"approximate")
        b_mean, mean = float(b["mean_ns"]), float(row["mean_ns"])
        limit = b_mean * (1.0 + max_regress)
        if mean > limit:
            failures.append(
                f"{name}: mean_ns {mean:.0f} > {limit:.0f} "
                f"(baseline {b_mean:.0f} +{max_regress * 100:.0f}%)")
        b_allocs = float(b.get("allocs_per_iter", 0.0))
        allocs = float(row.get("allocs_per_iter", 0.0))
        if b_allocs < ALLOC_FREE_BASE and \
                allocs > b_allocs + ALLOC_JITTER:
            failures.append(
                f"{name}: allocs_per_iter grew {b_allocs:.1f} -> "
                f"{allocs:.1f} on an alloc-free row")
    for name in base:
        if name not in new:
            notes.append(f"baseline row {name!r} missing from fresh "
                         f"output (bench not run?)")
    return failures, notes


def update(baseline_path, fresh):
    """Merge fresh rows into the baseline file by name."""
    if os.path.exists(baseline_path):
        doc = load(baseline_path)
    else:
        doc = {"schema": SCHEMA, "results": []}
    merged = by_name(doc)
    merged.update(by_name(fresh))
    doc["results"] = sorted(merged.values(), key=lambda r: r["name"])
    if doc["results"]:
        # The committed bootstrap note ("no rows tracked yet") is
        # stale once rows exist; replace it with the refresh recipe.
        doc["note"] = ("Tracked bench baseline — compared by "
                       "tools/bench_gate.py in CI. Refresh from a "
                       "trusted --quick run with --update and commit.")
    os.makedirs(os.path.dirname(baseline_path) or ".", exist_ok=True)
    with open(baseline_path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"updated {baseline_path}: {len(doc['results'])} tracked "
          f"row(s)")


def run_gate(baseline_path, fresh_path, max_regress, do_update):
    fresh = load(fresh_path)
    if do_update:
        update(baseline_path, fresh)
        return 0
    if os.path.exists(baseline_path):
        baseline = load(baseline_path)
    else:
        print(f"bench gate: no baseline at {baseline_path} "
              f"(bootstrap pending)")
        baseline = {"schema": SCHEMA, "results": []}
    failures, notes = compare(baseline, fresh, max_regress)
    for n in notes:
        print(f"bench gate [note] {n}")
    for f in failures:
        print(f"bench gate [FAIL] {f}")
    if failures:
        print(f"bench gate: {len(failures)} regression(s) against "
              f"{baseline_path}")
        return 1
    print(f"bench gate: OK ({len(by_name(fresh))} row(s) checked "
          f"against {baseline_path})")
    return 0


# --------------------------------------------------------- self-test

def row(name, mean_ns, allocs, quick=True):
    return {"name": name, "iters": 10, "mean_ns": mean_ns,
            "p50_ns": mean_ns, "p95_ns": mean_ns, "p99_ns": mean_ns,
            "frames_per_sec": 1e9 / mean_ns,
            "allocs_per_iter": allocs, "quick": quick, "threads": 2}


def doc(*rows):
    return {"schema": SCHEMA, "results": list(rows)}


def self_test():
    """Doctored-json unit tests of every gate rule."""
    checks = []

    def check(what, failures, want_fail):
        ok = bool(failures) == want_fail
        checks.append((what, ok, failures))
        status = "ok" if ok else "MISFIRE"
        print(f"self-test [{status}] {what}: "
              f"{failures if failures else 'no failures'}")

    base = doc(row("sim_step", 100.0, 0.0),
               row("serving_e2e", 50_000.0, 120.0))

    # Within the envelope: +10% mean, allocs flat.
    f, _ = compare(base, doc(row("sim_step", 110.0, 0.0),
                             row("serving_e2e", 54_000.0, 125.0)), 0.25)
    check("within-envelope passes", f, want_fail=False)

    # Injected mean regression: +60% on one row must fail.
    f, _ = compare(base, doc(row("sim_step", 160.0, 0.0),
                             row("serving_e2e", 50_000.0, 120.0)), 0.25)
    check("+60% mean_ns fails", f, want_fail=True)

    # Exactly at the limit passes; just beyond fails.
    f, _ = compare(base, doc(row("sim_step", 125.0, 0.0)), 0.25)
    check("at +25% passes", f, want_fail=False)
    f, _ = compare(base, doc(row("sim_step", 126.0, 0.0)), 0.25)
    check("just past +25% fails", f, want_fail=True)

    # Allocation crept into an alloc-free row.
    f, _ = compare(base, doc(row("sim_step", 100.0, 2.0)), 0.25)
    check("allocs 0 -> 2 on alloc-free row fails", f, want_fail=True)

    # Alloc growth on an already-allocating row is not gated.
    f, _ = compare(base, doc(row("serving_e2e", 50_000.0, 300.0)), 0.25)
    check("alloc growth on allocating row passes", f, want_fail=False)

    # Untracked fresh row and missing baseline row: notes, not failures.
    f, notes = compare(base, doc(row("sim_step", 100.0, 0.0),
                                 row("brand_new", 10.0, 0.0)), 0.25)
    check("untracked row passes", f, want_fail=False)
    assert any("untracked" in n for n in notes), notes
    assert any("missing from fresh" in n for n in notes), notes

    # Empty baseline (bootstrap) never fails.
    f, notes = compare(doc(), doc(row("sim_step", 999.0, 50.0)), 0.25)
    check("empty baseline bootstraps", f, want_fail=False)
    assert any("bootstrap" in n for n in notes), notes

    bad = [what for what, ok, _ in checks if not ok]
    if bad:
        print(f"self-test FAILED: {bad}")
        return 1
    print(f"self-test: all {len(checks)} gate rules behave")
    return 0


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", help="committed baseline json "
                    "(e.g. bench/baseline/BENCH_sim.json)")
    ap.add_argument("--fresh", help="freshly produced bench json")
    ap.add_argument("--max-regress", type=float, default=0.25,
                    help="allowed fractional mean_ns regression "
                    "(default 0.25)")
    ap.add_argument("--update", action="store_true",
                    help="merge fresh rows into the baseline instead "
                    "of comparing")
    ap.add_argument("--self-test", action="store_true",
                    help="verify the gate rules against doctored "
                    "documents")
    args = ap.parse_args()
    if args.self_test:
        sys.exit(self_test())
    if not args.baseline or not args.fresh:
        ap.error("--baseline and --fresh are required "
                 "(or use --self-test)")
    sys.exit(run_gate(args.baseline, args.fresh, args.max_regress,
                      args.update))


if __name__ == "__main__":
    main()
