#!/usr/bin/env python3
"""Validate a Chrome trace-event dump from the skydiver flight
recorder (``skydiver trace --addr ... --chrome``).

Structural rules:

* the document is an object with a ``traceEvents`` list;
* every event is a complete span (``"ph": "X"``) with numeric,
  non-negative ``ts``/``dur`` and a ``pid``/``tid``;
* every event's ``args`` carries a 32-hex-char ``trace`` id, a
  positive ``span`` id, a numeric ``parent`` and a boolean ``error``;
* span names come from the known stage vocabulary (PERF.md maps each
  to the code it measures);
* span ids are unique within a trace, and a span never lists itself
  as its parent. Parents may be absent from the dump (a backend's
  dump holds spans whose parent lives in the router's recorder — the
  cross-process stitch), so unresolved parents are fine; cycles and
  duplicates are not.

Semantic rules, per trace id:

* within one process (``pid``), the serving pipeline is ordered:
  ``queue`` must not end after ``compute`` ends, and ``compute`` must
  not end after ``write`` ends — the monotonic-interval contract the
  integration tests pin in-process, held here against any dump CI
  captures from a live gateway or router;
* a resolvable parent must belong to the same trace.

``--self-test`` checks every rule against doctored in-memory
documents and exits non-zero if any misfires — run before trusting
the validator, exactly like ``bench_gate.py --self-test``.
"""

import argparse
import json
import sys

STAGES = ("admission", "cost_predict", "queue", "batch", "compute",
          "encode", "write", "route", "attempt", "scale")
# Intra-process pipeline checkpoints, in must-not-end-later order.
PIPELINE = ("queue", "compute", "write")


def validate(doc):
    """Return a list of rule violations (empty = valid)."""
    errs = []
    if not isinstance(doc, dict):
        return ["document is not a JSON object"]
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return ["'traceEvents' must be a list"]

    # (trace, pid) -> name -> latest end; trace -> {span ids}
    spans = {}
    parents = {}
    ends = {}
    for i, ev in enumerate(events):
        where = f"event[{i}]"
        if not isinstance(ev, dict):
            errs.append(f"{where}: not an object")
            continue
        if ev.get("ph") != "X":
            errs.append(f"{where}: ph {ev.get('ph')!r} != 'X'")
            continue
        name = ev.get("name")
        if name not in STAGES:
            errs.append(f"{where}: unknown stage {name!r}")
        for k in ("ts", "dur"):
            v = ev.get(k)
            if not isinstance(v, (int, float)) or v < 0:
                errs.append(f"{where}: {k} must be a number >= 0, "
                            f"got {v!r}")
        if not isinstance(ev.get("pid"), (int, float)):
            errs.append(f"{where}: missing numeric pid")
        if not isinstance(ev.get("tid"), (int, float)):
            errs.append(f"{where}: missing numeric tid")
        args = ev.get("args")
        if not isinstance(args, dict):
            errs.append(f"{where}: missing args object")
            continue
        trace = args.get("trace")
        if not (isinstance(trace, str) and len(trace) == 32
                and all(c in "0123456789abcdef" for c in trace)):
            errs.append(f"{where}: args.trace must be 32 hex chars, "
                        f"got {trace!r}")
            continue
        span = args.get("span")
        if not isinstance(span, (int, float)) or span <= 0:
            errs.append(f"{where}: args.span must be > 0, got "
                        f"{span!r}")
            continue
        parent = args.get("parent")
        if not isinstance(parent, (int, float)) or parent < 0:
            errs.append(f"{where}: args.parent must be >= 0, got "
                        f"{parent!r}")
            continue
        if not isinstance(args.get("error"), bool):
            errs.append(f"{where}: args.error must be a boolean")
        span, parent = int(span), int(parent)
        if parent == span:
            errs.append(f"{where}: span {span} is its own parent")
        ids = spans.setdefault(trace, set())
        if span in ids:
            errs.append(f"{where}: duplicate span id {span} in "
                        f"trace {trace}")
        ids.add(span)
        parents.setdefault(trace, {})[span] = parent
        if name in PIPELINE:
            key = (trace, ev.get("pid"))
            end = float(ev.get("ts") or 0) + float(ev.get("dur") or 0)
            ends.setdefault(key, {})[name] = \
                max(end, ends.get(key, {}).get(name, 0.0))

    # Resolvable parents stay inside their trace, acyclically.
    for trace, links in parents.items():
        for span, parent in links.items():
            seen = set()
            cur = span
            while cur in links and links[cur] in links:
                if cur in seen:
                    errs.append(f"trace {trace}: parent cycle at "
                                f"span {span}")
                    break
                seen.add(cur)
                cur = links[cur]

    # Pipeline order inside one process: a stage may not end after
    # the stage that consumes its output. (Float slack for the
    # ns -> us rounding the dump performs.)
    eps = 0.01
    for (trace, pid), stages in ends.items():
        for a, b in zip(PIPELINE, PIPELINE[1:]):
            if a in stages and b in stages \
                    and stages[a] > stages[b] + eps:
                errs.append(
                    f"trace {trace} pid {pid}: {a} ends at "
                    f"{stages[a]:.3f}us, after {b} ends at "
                    f"{stages[b]:.3f}us")

    if not errs and not events:
        errs.append("dump contains no span events (tracing off, or "
                    "no completed requests?)")
    return errs


def check_file(path, min_traces):
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        print(f"validate_trace: {path}: {e}", file=sys.stderr)
        return 1
    errs = validate(doc)
    traces = {e.get("args", {}).get("trace")
              for e in doc.get("traceEvents", [])
              if isinstance(e, dict)} - {None}
    if len(traces) < min_traces:
        errs.append(f"only {len(traces)} trace(s), want >= "
                    f"{min_traces}")
    for e in errs:
        print(f"validate_trace [FAIL] {e}", file=sys.stderr)
    if errs:
        print(f"validate_trace: {path}: {len(errs)} violation(s)",
              file=sys.stderr)
        return 1
    n = len(doc["traceEvents"])
    print(f"{path} OK: {n} span event(s) across {len(traces)} "
          f"trace(s)")
    return 0


# --------------------------------------------------------- self-test

def ev(trace="ab" * 16, name="compute", span=2, parent=1, ts=10.0,
       dur=5.0, error=False, pid=1, tid=0):
    return {"name": name, "cat": "skydiver", "ph": "X", "ts": ts,
            "dur": dur, "pid": pid, "tid": tid,
            "args": {"trace": trace, "span": span, "parent": parent,
                     "error": error, "a": 0, "b": 0}}


def self_test():
    checks = []

    def check(what, doc, want_fail):
        errs = validate(doc)
        ok = bool(errs) == want_fail
        checks.append((what, ok))
        status = "ok" if ok else "MISFIRE"
        print(f"self-test [{status}] {what}: "
              f"{errs if errs else 'no violations'}")

    good = {"traceEvents": [
        ev(name="route", span=1, parent=0, ts=0.0, dur=100.0),
        ev(name="attempt", span=2, parent=1, ts=1.0, dur=90.0),
        ev(name="queue", span=3, parent=2, ts=2.0, dur=10.0, pid=2),
        ev(name="compute", span=4, parent=2, ts=12.0, dur=20.0,
           pid=2),
        ev(name="write", span=5, parent=2, ts=33.0, dur=1.0, pid=2),
    ]}
    check("well-formed stitched dump passes", good, want_fail=False)

    check("empty dump fails", {"traceEvents": []}, want_fail=True)
    check("non-object fails", [], want_fail=True)
    check("missing traceEvents fails", {}, want_fail=True)

    check("unknown stage name fails",
          {"traceEvents": [ev(name="teleport")]}, want_fail=True)
    check("incomplete-phase event fails",
          {"traceEvents": [dict(ev(), ph="B")]}, want_fail=True)
    check("negative duration fails",
          {"traceEvents": [ev(dur=-1.0)]}, want_fail=True)
    check("malformed trace id fails",
          {"traceEvents": [ev(trace="xyz")]}, want_fail=True)
    check("zero span id fails",
          {"traceEvents": [ev(span=0)]}, want_fail=True)
    check("self-parent fails",
          {"traceEvents": [ev(span=7, parent=7)]}, want_fail=True)
    check("duplicate span id in one trace fails",
          {"traceEvents": [ev(span=2), ev(span=2, ts=20.0)]},
          want_fail=True)

    # Unresolved parent = cross-process stitch: must PASS.
    check("unresolved (cross-process) parent passes",
          {"traceEvents": [ev(name="compute", span=9, parent=777)]},
          want_fail=False)

    # Pipeline inversion inside one process: queue ending after
    # compute has ended.
    bad_order = {"traceEvents": [
        ev(name="queue", span=3, parent=0, ts=50.0, dur=40.0),
        ev(name="compute", span=4, parent=0, ts=12.0, dur=20.0),
    ]}
    check("queue ending after compute fails", bad_order,
          want_fail=True)
    # The same inversion across two pids is legitimate concurrency.
    ok_order = {"traceEvents": [
        ev(name="queue", span=3, parent=0, ts=50.0, dur=40.0, pid=1),
        ev(name="compute", span=4, parent=0, ts=12.0, dur=20.0,
           pid=2),
    ]}
    check("stage overlap across processes passes", ok_order,
          want_fail=False)

    bad = [what for what, ok in checks if not ok]
    if bad:
        print(f"self-test FAILED: {bad}")
        return 1
    print(f"self-test: all {len(checks)} validator rules behave")
    return 0


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("path", nargs="?",
                    help="Chrome trace-event JSON to validate")
    ap.add_argument("--min-traces", type=int, default=1,
                    help="require at least N distinct trace ids "
                    "(default 1)")
    ap.add_argument("--self-test", action="store_true",
                    help="verify the validator rules against "
                    "doctored documents")
    args = ap.parse_args()
    if args.self_test:
        sys.exit(self_test())
    if not args.path:
        ap.error("a dump path is required (or use --self-test)")
    sys.exit(check_file(args.path, args.min_traces))


if __name__ == "__main__":
    main()
