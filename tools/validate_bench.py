#!/usr/bin/env python3
"""Validate a BENCH_*.json produced by the bench binaries.

Extracted from the inline CI snippets so the same check runs locally:

    python3 tools/validate_bench.py BENCH_sim.json --kind sim
    python3 tools/validate_bench.py BENCH_serving.json --kind serving

* schema must be ``skydiver-bench-v1`` with a non-empty ``results``
  list;
* every row carries the tracked keys (serving rows additionally
  ``p99_ns`` and a positive ``frames_per_sec``);
* serving output must contain the canonical row set (loopback rtt/e2e,
  the two mixed multi-model rows, the skewed FIFO/cost dispatch pair,
  the c10k reactor row, the cluster-router row, the tracing-tax
  pipelined/traced pair, the temporal-kernels-off A/B row, and the
  degraded-overload and autoscaling rows);
* sim output must contain the bit-parallel temporal-kernel rows
  (``sim_temporal_{conv,dense,frame}``).
"""

import argparse
import json
import sys

SCHEMA = "skydiver-bench-v1"
COMMON_KEYS = ("name", "iters", "mean_ns", "p50_ns", "p95_ns",
               "frames_per_sec", "allocs_per_iter")
SERVING_KEYS = COMMON_KEYS + ("p99_ns",)
SERVING_ROWS = (
    "serving_loopback_rtt",
    "serving_loopback_e2e",
    "serving_mixed_classifier",
    "serving_mixed_segmenter",
    "serving_skewed_fifo",
    "serving_skewed_cost",
    "serving_c10k",
    "serving_cluster",
    "serving_pipelined",
    "serving_traced",
    "serving_temporal_off",
    "serving_degraded",
    "serving_autoscale",
)
SIM_ROWS = (
    "sim_temporal_conv",
    "sim_temporal_dense",
    "sim_temporal_frame",
)


def fail(msg):
    print(f"validate_bench: {msg}", file=sys.stderr)
    sys.exit(1)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("path")
    ap.add_argument("--kind", choices=("sim", "serving"),
                    default="sim")
    args = ap.parse_args()

    try:
        with open(args.path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        fail(f"{args.path}: {e}")

    if doc.get("schema") != SCHEMA:
        fail(f"{args.path}: schema {doc.get('schema')!r} != {SCHEMA!r}")
    rows = doc.get("results")
    if not isinstance(rows, list) or not rows:
        fail(f"{args.path}: no bench results")

    keys = SERVING_KEYS if args.kind == "serving" else COMMON_KEYS
    for r in rows:
        for k in keys:
            if k not in r:
                fail(f"{args.path}: row {r.get('name', r)!r} missing "
                     f"{k!r}")
        if args.kind == "serving" and not r["frames_per_sec"] > 0:
            fail(f"{args.path}: row {r['name']!r} has non-positive "
                 f"frames_per_sec")

    want = SERVING_ROWS if args.kind == "serving" else SIM_ROWS
    names = {r["name"] for r in rows}
    missing = [w for w in want if w not in names]
    if missing:
        fail(f"{args.path}: missing {args.kind} rows {missing} "
             f"(have {sorted(names)})")

    print(f"{args.path} OK: {len(rows)} entries ({args.kind})")


if __name__ == "__main__":
    main()
