#!/usr/bin/env python3
"""Cross-check docs/OPERATIONS.md against the source of truth.

The operator guide documents every CLI flag and every Prometheus
series the serving tier renders. Documentation drifts; this validator
makes drift a CI failure instead of a support ticket:

* every metric name rendered by the gateway
  (``rust/src/server/server.rs``), the cluster router
  (``rust/src/cluster/router.rs``) and the shared observability layer
  (``rust/src/obs/*.rs``) must appear in OPERATIONS.md;
* every ``skydiver_*`` name OPERATIONS.md mentions must exist in that
  rendered set (no stale series after a rename);
* every flag in ``FLAG_SPECS`` (``rust/src/main.rs``) must appear as
  ``--flag`` in OPERATIONS.md, and every ``--flag`` the doc mentions
  must be a real flag.

Comment lines in the Rust sources are ignored so prose shorthand like
``skydiver_autoscale_{workers,events_total}`` doesn't pollute the
extracted name set. Histogram suffixes (``_bucket``/``_sum``/
``_count``) are folded into their base series.

``--self-test`` runs every rule against doctored in-memory inputs and
exits non-zero on a misfire, like ``validate_trace.py --self-test``.
"""

import argparse
import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOC = os.path.join(REPO, "docs", "OPERATIONS.md")
MAIN = os.path.join(REPO, "rust", "src", "main.rs")
METRIC_SOURCES = (
    os.path.join(REPO, "rust", "src", "server", "server.rs"),
    os.path.join(REPO, "rust", "src", "cluster", "router.rs"),
)
OBS_DIR = os.path.join(REPO, "rust", "src", "obs")

METRIC_RE = re.compile(r"skydiver_[a-z0-9_]*[a-z0-9]")
FLAG_SPEC_RE = re.compile(r'^\s*\("([a-z][a-z0-9-]*)",\s*(?:true|false)\)')
DOC_FLAG_RE = re.compile(r"--([a-z][a-z0-9-]*)")
HISTO_SUFFIXES = ("_bucket", "_sum", "_count")


def fold_histogram(name):
    """skydiver_stage_us_bucket -> skydiver_stage_us."""
    for suf in HISTO_SUFFIXES:
        if name.endswith(suf):
            return name[: -len(suf)]
    return name


def metric_names_from_rust(text):
    """Names in string-literal/render code, skipping // comments."""
    names = set()
    for line in text.splitlines():
        if line.lstrip().startswith("//"):
            continue
        for m in METRIC_RE.findall(line):
            names.add(fold_histogram(m))
    return names


def metric_names_from_doc(text):
    names = set()
    in_code = False
    for line in text.splitlines():
        if line.lstrip().startswith("```"):
            in_code = not in_code
            continue
        for m in METRIC_RE.findall(line):
            names.add(fold_histogram(m))
    return names


def flags_from_main(text):
    flags = set()
    in_specs = False
    for line in text.splitlines():
        if "FLAG_SPECS" in line and "&[" in line:
            in_specs = True
            continue
        if in_specs:
            if line.strip().startswith("];"):
                break
            m = FLAG_SPEC_RE.match(line)
            if m:
                flags.add(m.group(1))
    return flags


def flags_from_doc(text):
    return set(DOC_FLAG_RE.findall(text))


def cross_check(doc_text, rust_metrics, spec_flags):
    """Return a list of violations (empty = docs and source agree)."""
    errs = []
    doc_metrics = metric_names_from_doc(doc_text)
    doc_flags = flags_from_doc(doc_text)

    for name in sorted(rust_metrics - doc_metrics):
        errs.append(f"metric {name} is rendered but not documented "
                    f"in OPERATIONS.md")
    for name in sorted(doc_metrics - rust_metrics):
        errs.append(f"metric {name} is documented but no longer "
                    f"rendered (stale name?)")
    for flag in sorted(spec_flags - doc_flags):
        errs.append(f"flag --{flag} is in FLAG_SPECS but not "
                    f"documented in OPERATIONS.md")
    for flag in sorted(doc_flags - spec_flags):
        errs.append(f"flag --{flag} is documented but unknown to the "
                    f"CLI (stale flag?)")
    return errs


def load(path):
    try:
        with open(path) as f:
            return f.read()
    except OSError as e:
        print(f"validate_ops_docs: {path}: {e}", file=sys.stderr)
        sys.exit(1)


def run():
    rust_metrics = set()
    sources = list(METRIC_SOURCES)
    if os.path.isdir(OBS_DIR):
        sources += [os.path.join(OBS_DIR, f)
                    for f in sorted(os.listdir(OBS_DIR))
                    if f.endswith(".rs")]
    for path in sources:
        rust_metrics |= metric_names_from_rust(load(path))
    spec_flags = flags_from_main(load(MAIN))
    if not rust_metrics:
        print("validate_ops_docs: extracted no metric names — "
              "extraction regex broken?", file=sys.stderr)
        return 1
    if not spec_flags:
        print("validate_ops_docs: extracted no FLAG_SPECS flags — "
              "main.rs layout changed?", file=sys.stderr)
        return 1
    errs = cross_check(load(DOC), rust_metrics, spec_flags)
    for e in errs:
        print(f"validate_ops_docs [FAIL] {e}", file=sys.stderr)
    if errs:
        print(f"validate_ops_docs: {len(errs)} violation(s)",
              file=sys.stderr)
        return 1
    print(f"docs/OPERATIONS.md OK: {len(rust_metrics)} metric "
          f"name(s), {len(spec_flags)} flag(s) cross-checked")
    return 0


# --------------------------------------------------------- self-test

RUST_FIXTURE = """
// comment mentioning skydiver_phantom_series is ignored
let _ = writeln!(out, "# TYPE skydiver_served_total counter");
let _ = writeln!(out, "skydiver_served_total {v}");
push_labelled(&mut out, "skydiver_queue_depth", "gauge", d);
out.push_str("skydiver_stage_us_bucket{le=\\"1\\"} 0\\n");
"""

MAIN_FIXTURE = """
const FLAG_SPECS: &[(&str, bool)] = &[
    ("addr", true),
    ("workers", true),
    ("golden", false),
];
"""

GOOD_DOC = """
| `skydiver_served_total` | counter | served |
| `skydiver_queue_depth` | gauge | depth |
`skydiver_stage_us` histogram (`skydiver_stage_us_bucket`).
Flags: `--addr`, `--workers N`, `--golden`.
"""


def self_test():
    checks = []

    def check(what, doc, want_errs):
        metrics = metric_names_from_rust(RUST_FIXTURE)
        flags = flags_from_main(MAIN_FIXTURE)
        errs = cross_check(doc, metrics, flags)
        ok = bool(errs) == want_errs
        checks.append((what, ok))
        status = "ok" if ok else "MISFIRE"
        print(f"self-test [{status}] {what}: "
              f"{errs if errs else 'no violations'}")

    metrics = metric_names_from_rust(RUST_FIXTURE)
    assert_ok = metrics == {"skydiver_served_total",
                            "skydiver_queue_depth",
                            "skydiver_stage_us"}
    checks.append(("extraction folds histograms, skips comments",
                   assert_ok))
    print(f"self-test [{'ok' if assert_ok else 'MISFIRE'}] "
          f"extracted {sorted(metrics)}")

    check("complete doc passes", GOOD_DOC, want_errs=False)
    check("missing metric fails",
          GOOD_DOC.replace("skydiver_queue_depth` | gauge", "x"),
          want_errs=True)
    check("stale metric fails",
          GOOD_DOC + "\n`skydiver_retired_series` gauge\n",
          want_errs=True)
    check("missing flag fails",
          GOOD_DOC.replace("`--golden`", "x"), want_errs=True)
    check("stale flag fails",
          GOOD_DOC + "\nuse `--turbo` for speed\n", want_errs=True)

    bad = [what for what, ok in checks if not ok]
    if bad:
        print(f"self-test FAILED: {bad}")
        return 1
    print(f"self-test: all {len(checks)} validator rules behave")
    return 0


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--self-test", action="store_true",
                    help="verify the cross-check rules against "
                    "doctored inputs")
    args = ap.parse_args()
    sys.exit(self_test() if args.self_test else run())


if __name__ == "__main__":
    main()
